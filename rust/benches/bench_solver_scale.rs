//! Bench E9: solver scalability — the rebuilt MILP (bounded-variable
//! revised simplex + warm-basis branch-and-bound) vs the greedy
//! heuristic AND vs the preserved seed solver (dense tableau, bounds as
//! rows, cold node solves), plus the rolling-horizon scale-out to 256
//! concurrent jobs. Supports the paper's premise that the joint solve is
//! cheap enough to re-run on every introspection/arrival event.
//!
//! Emits a machine-readable perf record to `BENCH_solver_scale.json`
//! (override with `SATURN_BENCH_OUT`).
//!
//! Run: `cargo bench --bench bench_solver_scale`

use saturn::bench::{fmt_s, print_header, print_stats, Bencher};
use saturn::cluster::ClusterSpec;
use saturn::parallelism::default_library;
use saturn::saturn::solver::{plan_selection_colgen, plan_selection_probe,
                             sharded_probe, solve_joint, SolverMode,
                             SolverStats};
use saturn::solver::milp::MilpEngine;
use saturn::trials::{profile_analytic, ProfileTable};
use saturn::util::json::Json;
use saturn::workload::toy_workload;

fn remaining(jobs: &[saturn::workload::Job]) -> Vec<(usize, u64)> {
    jobs.iter().map(|j| (j.id, j.total_steps())).collect()
}

fn setup(n: usize, cluster: &ClusterSpec)
    -> (Vec<(usize, u64)>, ProfileTable) {
    let jobs = toy_workload(n);
    let lib = default_library();
    let profiles = profile_analytic(&jobs, &lib, cluster);
    (remaining(&jobs), profiles)
}

fn main() {
    let bencher = Bencher::from_env();
    let cluster = ClusterSpec::p4d(2);
    let fast = std::env::var("SATURN_BENCH_FAST").as_deref() == Ok("1");

    print_header("joint MILP vs greedy heuristic (solve wall time)");
    let mut sizes_json: Vec<Json> = Vec::new();
    for n in [4usize, 8, 12, 24, 48] {
        let (remaining, profiles) = setup(n, &cluster);

        let mut quality = (0.0, 0.0);
        let mut last_stats = SolverStats::default();
        let s = bencher.run_fn(&format!("joint/jobs={n}"), || {
            let (plan, st) = solve_joint(&remaining, &profiles, &cluster,
                                         SolverMode::Joint);
            quality.0 = plan.predicted_makespan_s;
            last_stats = st;
        });
        saturn::bench::print_stats(&s);
        let joint_wall = s.mean_s;
        let s = bencher.run_fn(&format!("greedy/jobs={n}"), || {
            let (plan, _) = solve_joint(&remaining, &profiles, &cluster,
                                        SolverMode::Heuristic);
            quality.1 = plan.predicted_makespan_s;
        });
        saturn::bench::print_stats(&s);
        println!("{:<44} joint {:.0}s vs greedy {:.0}s ({:+.1}%)  \
                  [{} nodes, {} pivots, warm {:.0}%]",
                 format!("  plan quality/jobs={n}"), quality.0, quality.1,
                 100.0 * (quality.1 - quality.0) / quality.0.max(1e-9),
                 last_stats.milp_nodes, last_stats.lp_pivots,
                 100.0 * last_stats.warm_hit_rate());
        sizes_json.push(Json::obj(vec![
            ("jobs", Json::num(n as f64)),
            ("joint_wall_s", Json::num(joint_wall)),
            ("greedy_wall_s", Json::num(s.mean_s)),
            ("joint_makespan_s", Json::num(quality.0)),
            ("greedy_makespan_s", Json::num(quality.1)),
            ("milp_nodes", Json::num(last_stats.milp_nodes as f64)),
            ("lp_pivots", Json::num(last_stats.lp_pivots as f64)),
            ("warm_hit_rate", Json::num(last_stats.warm_hit_rate())),
        ]));
    }

    // ------------------------------------------------------------------
    // seed engine vs revised engine at matched (1e-6) objectives
    // ------------------------------------------------------------------
    print_header("revised vs SEED dense engine (plan-selection MILP, n=48)");
    let seed_n = 48usize;
    let (remaining48, profiles48) = setup(seed_n, &cluster);
    let reps = if fast { 1 } else { 3 };
    let mut revised_wall = f64::INFINITY;
    let mut seed_wall = f64::INFINITY;
    let mut revised_obj = 0.0;
    let mut seed_obj = 0.0;
    for _ in 0..reps {
        let (obj, st) = plan_selection_probe(&remaining48, &profiles48,
                                             &cluster, MilpEngine::Revised)
            .expect("revised probe solved");
        revised_obj = obj;
        revised_wall = revised_wall.min(st.wall_s);
        let (obj, st) = plan_selection_probe(&remaining48, &profiles48,
                                             &cluster,
                                             MilpEngine::DenseReference)
            .expect("seed probe solved");
        seed_obj = obj;
        seed_wall = seed_wall.min(st.wall_s);
    }
    let speedup = seed_wall / revised_wall.max(1e-12);
    let obj_delta = (revised_obj - seed_obj).abs()
        / seed_obj.abs().max(1.0);
    println!("{:<44} {:>10}", "seed dense engine", fmt_s(seed_wall));
    println!("{:<44} {:>10}", "revised engine", fmt_s(revised_wall));
    println!("revised speedup over seed: {speedup:.1}x wall \
              (objective {revised_obj:.3}s vs {seed_obj:.3}s, \
              rel delta {obj_delta:.2e})");
    assert!(obj_delta <= 1e-6,
            "engines disagree on the optimum: {revised_obj} vs {seed_obj}");

    // ------------------------------------------------------------------
    // rolling-horizon scale-out
    // ------------------------------------------------------------------
    print_header("rolling-horizon joint solve (window 32 / overlap 8)");
    let big_cluster = ClusterSpec::p4d(8);
    let mut rolling_json: Vec<Json> = Vec::new();
    for n in [96usize, 192, 256] {
        let (remaining, profiles) = setup(n, &big_cluster);
        let mut quality = (0.0, 0.0);
        let mut last_stats = SolverStats::default();
        let s = bencher.run_fn(&format!("rolling/jobs={n}"), || {
            let (plan, st) = solve_joint(&remaining, &profiles, &big_cluster,
                                         SolverMode::rolling_default());
            quality.0 = plan.predicted_makespan_s;
            last_stats = st;
        });
        print_stats(&s);
        let rolling_wall = s.mean_s;
        let s = bencher.run_fn(&format!("greedy/jobs={n}"), || {
            let (plan, _) = solve_joint(&remaining, &profiles, &big_cluster,
                                        SolverMode::Heuristic);
            quality.1 = plan.predicted_makespan_s;
        });
        print_stats(&s);
        println!("{:<44} rolling {:.0}s vs greedy {:.0}s ({:+.1}%)  \
                  [{} windows, {} nodes, warm {:.0}%]{}",
                 format!("  plan quality/jobs={n}"), quality.0, quality.1,
                 100.0 * (quality.1 - quality.0) / quality.0.max(1e-9),
                 last_stats.windows, last_stats.milp_nodes,
                 100.0 * last_stats.warm_hit_rate(),
                 if rolling_wall < 1.0 { "" } else { "  ** >1s **" });
        rolling_json.push(Json::obj(vec![
            ("jobs", Json::num(n as f64)),
            ("wall_s", Json::num(rolling_wall)),
            ("greedy_wall_s", Json::num(s.mean_s)),
            ("makespan_s", Json::num(quality.0)),
            ("greedy_makespan_s", Json::num(quality.1)),
            ("windows", Json::num(last_stats.windows as f64)),
            ("milp_nodes", Json::num(last_stats.milp_nodes as f64)),
            ("warm_hit_rate", Json::num(last_stats.warm_hit_rate())),
            ("sub_second", Json::Bool(rolling_wall < 1.0)),
        ]));
    }

    // ------------------------------------------------------------------
    // column generation vs the full candidate grid (same 1e-6 budgets)
    // ------------------------------------------------------------------
    print_header("column generation vs full grid (restricted master)");
    let full_columns: usize = remaining48
        .iter()
        .map(|&(id, _)| profiles48.candidate_plans(id).len())
        .sum();
    let (colgen_obj, colgen_stats) =
        plan_selection_colgen(&remaining48, &profiles48, &cluster)
            .expect("colgen probe solved");
    let colgen_delta = (colgen_obj - revised_obj).abs()
        / revised_obj.abs().max(1.0);
    println!("colgen objective {colgen_obj:.3}s vs full grid \
              {revised_obj:.3}s (rel delta {colgen_delta:.2e})");
    println!("columns: {} seed + {} priced of {} in the full grid",
             seed_n, colgen_stats.columns_priced, full_columns);
    assert!(colgen_delta <= 1e-6,
            "column generation missed the full-grid optimum: \
             {colgen_obj} vs {revised_obj}");

    // ------------------------------------------------------------------
    // sharded vs monolithic (direct at n=96, bound-relative at n=256)
    // ------------------------------------------------------------------
    print_header("sharded cells vs monolithic solve");
    let (remaining96, profiles96) = setup(96, &big_cluster);
    let (mono_obj, mono_stats) =
        plan_selection_probe(&remaining96, &profiles96, &big_cluster,
                             MilpEngine::Revised)
            .expect("monolithic probe solved");
    let (shard_obj, shard96_stats) =
        sharded_probe(&remaining96, &profiles96, &big_cluster, 32, 4)
            .expect("sharded probe solved");
    let direct_gap = (shard_obj - mono_obj) / mono_obj.max(1e-9);
    println!("n=96: sharded {shard_obj:.3}s ({} cells, {} columns) vs \
              monolithic {mono_obj:.3}s — gap {:.2}%",
             shard96_stats.cells, shard96_stats.columns_priced,
             100.0 * direct_gap);
    assert!(direct_gap <= 0.05,
            "sharded solve lost >5% to the monolithic optimum: \
             {shard_obj} vs {mono_obj}");

    let (remaining256, profiles256) = setup(256, &big_cluster);
    let (plan256, stats256) =
        solve_joint(&remaining256, &profiles256, &big_cluster,
                    SolverMode::sharded_default());
    println!("n=256: sharded makespan {:.0}s, {} cells, shard gap \
              {:.2}% vs monolithic lower bound",
             plan256.predicted_makespan_s, stats256.cells,
             100.0 * stats256.shard_gap);
    assert!(stats256.shard_gap <= 0.05,
            "n=256 shard gap above 5%: {}", stats256.shard_gap);

    // ------------------------------------------------------------------
    // sharded scale-out: thousands of jobs
    // ------------------------------------------------------------------
    print_header("sharded joint solve (cell_size 64, 4 workers)");
    let scale_bencher = Bencher::new(0, if fast { 1 } else { 3 });
    let mut scale_json: Vec<Json> = Vec::new();
    for n in [512usize, 1024, 4096] {
        let (remaining, profiles) = setup(n, &big_cluster);
        let mut makespan = 0.0;
        let mut last_stats = SolverStats::default();
        let s = scale_bencher.run_fn(&format!("sharded/jobs={n}"), || {
            let (plan, st) = solve_joint(&remaining, &profiles,
                                         &big_cluster,
                                         SolverMode::sharded_default());
            makespan = plan.predicted_makespan_s;
            last_stats = st;
        });
        print_stats(&s);
        println!("{:<44} {} cells, {} columns priced, {} eta / {} \
                  refactor, gap {:.2}%",
                 format!("  sharded counters/jobs={n}"), last_stats.cells,
                 last_stats.columns_priced, last_stats.eta_updates,
                 last_stats.refactorizations, 100.0 * last_stats.shard_gap);
        scale_json.push(Json::obj(vec![
            ("jobs", Json::num(n as f64)),
            ("wall_s", Json::num(s.mean_s)),
            ("p99_s", Json::num(s.p99_s)),
            ("makespan_s", Json::num(makespan)),
            ("cells", Json::num(last_stats.cells as f64)),
            ("columns_priced",
             Json::num(last_stats.columns_priced as f64)),
            ("eta_updates", Json::num(last_stats.eta_updates as f64)),
            ("refactorizations",
             Json::num(last_stats.refactorizations as f64)),
            ("shard_gap", Json::num(last_stats.shard_gap)),
            ("greedy_fallbacks",
             Json::num(last_stats.greedy_fallbacks as f64)),
            ("solved", Json::Bool(makespan > 0.0)),
        ]));
    }

    print_header("exact time-indexed MILP (small instances only)");
    for n in [3usize, 4] {
        let (remaining, profiles) = setup(n, &cluster);
        let s = bencher.run_fn(&format!("exact-slots/jobs={n}"), || {
            let _ = solve_joint(&remaining, &profiles, &cluster,
                                SolverMode::ExactSlots { slots: 6 });
        });
        saturn::bench::print_stats(&s);
    }

    // machine-readable perf record
    let out = std::env::var("SATURN_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_solver_scale.json".to_string());
    let record = Json::obj(vec![
        ("bench", Json::str("solver_scale")),
        ("gpus", Json::num(cluster.total_gpus() as f64)),
        ("rolling_gpus", Json::num(big_cluster.total_gpus() as f64)),
        ("sizes", Json::arr(sizes_json.into_iter())),
        ("rolling", Json::arr(rolling_json.into_iter())),
        ("seed_comparison", Json::obj(vec![
            ("jobs", Json::num(seed_n as f64)),
            ("seed_wall_s", Json::num(seed_wall)),
            ("revised_wall_s", Json::num(revised_wall)),
            ("speedup", Json::num(speedup)),
            ("seed_objective_s", Json::num(seed_obj)),
            ("revised_objective_s", Json::num(revised_obj)),
            ("objective_rel_delta", Json::num(obj_delta)),
        ])),
        ("colgen_comparison", Json::obj(vec![
            ("jobs", Json::num(seed_n as f64)),
            ("colgen_objective_s", Json::num(colgen_obj)),
            ("full_grid_objective_s", Json::num(revised_obj)),
            ("objective_rel_delta", Json::num(colgen_delta)),
            ("columns_priced",
             Json::num(colgen_stats.columns_priced as f64)),
            ("full_grid_columns", Json::num(full_columns as f64)),
        ])),
        ("shard_comparison", Json::obj(vec![
            ("jobs", Json::num(96.0)),
            ("sharded_objective_s", Json::num(shard_obj)),
            ("monolithic_objective_s", Json::num(mono_obj)),
            ("gap", Json::num(direct_gap)),
            ("monolithic_wall_s", Json::num(mono_stats.wall_s)),
            ("sharded_wall_s", Json::num(shard96_stats.wall_s)),
            ("gap_256", Json::num(stats256.shard_gap)),
        ])),
        ("scale", Json::arr(scale_json.into_iter())),
    ]);
    std::fs::write(&out, record.to_string()).expect("writing perf record");
    println!("\nwrote {out}");
}
