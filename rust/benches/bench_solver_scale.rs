//! Bench E9: solver scalability — MILP (Joint) vs greedy Heuristic as the
//! multi-job grows. Supports the paper's premise that solving is cheap
//! enough to re-run under introspection.
//!
//! Run: `cargo bench --bench bench_solver_scale`

use saturn::bench::{print_header, Bencher};
use saturn::cluster::ClusterSpec;
use saturn::parallelism::default_library;
use saturn::saturn::solver::{solve_joint, SolverMode};
use saturn::trials::profile_analytic;
use saturn::workload::toy_workload;

fn main() {
    let bencher = Bencher::from_env();
    let cluster = ClusterSpec::p4d(2);
    let lib = default_library();

    print_header("joint MILP vs greedy heuristic (solve wall time)");
    for n in [4usize, 8, 12, 24, 48] {
        let jobs = toy_workload(n);
        let profiles = profile_analytic(&jobs, &lib, &cluster);
        let remaining: Vec<(usize, u64)> =
            jobs.iter().map(|j| (j.id, j.total_steps())).collect();

        let mut quality = (0.0, 0.0);
        let s = bencher.run_fn(&format!("joint/jobs={n}"), || {
            let (plan, _) = solve_joint(&remaining, &profiles, &cluster,
                                        SolverMode::Joint);
            quality.0 = plan.predicted_makespan_s;
        });
        saturn::bench::print_stats(&s);
        let s = bencher.run_fn(&format!("greedy/jobs={n}"), || {
            let (plan, _) = solve_joint(&remaining, &profiles, &cluster,
                                        SolverMode::Heuristic);
            quality.1 = plan.predicted_makespan_s;
        });
        saturn::bench::print_stats(&s);
        println!("{:<44} joint {:.0}s vs greedy {:.0}s ({:+.1}%)",
                 format!("  plan quality/jobs={n}"), quality.0, quality.1,
                 100.0 * (quality.1 - quality.0) / quality.0.max(1e-9));
    }

    print_header("exact time-indexed MILP (small instances only)");
    for n in [3usize, 4] {
        let jobs = toy_workload(n);
        let profiles = profile_analytic(&jobs, &lib, &cluster);
        let remaining: Vec<(usize, u64)> =
            jobs.iter().map(|j| (j.id, j.total_steps())).collect();
        let s = bencher.run_fn(&format!("exact-slots/jobs={n}"), || {
            let _ = solve_joint(&remaining, &profiles, &cluster,
                                SolverMode::ExactSlots { slots: 6 });
        });
        saturn::bench::print_stats(&s);
    }
}
