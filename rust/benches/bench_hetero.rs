//! Bench E14: heterogeneous fleets — the joint solver on a mixed
//! A100+H100 fleet vs a homogeneous all-A100 fleet of (approximately)
//! equivalent peak FLOPs, plus the DEGENERATE single-class probe: an
//! all-A100 fleet routed through the per-class machinery must reproduce
//! the pre-heterogeneity pooled formulation's objective to 1e-6 (ISSUE 3
//! acceptance bar; also asserted in CI from the emitted record).
//!
//! Emits a machine-readable perf record to `BENCH_hetero.json`
//! (override with `SATURN_BENCH_OUT`).
//!
//! Run: `cargo bench --bench bench_hetero`

use saturn::bench::{print_header, print_stats, Bencher};
use saturn::cluster::ClusterSpec;
use saturn::parallelism::default_library;
use saturn::saturn::plan::SaturnPlan;
use saturn::saturn::solver::{plan_selection_probe,
                             plan_selection_probe_pooled, solve_joint,
                             SolverMode};
use saturn::solver::milp::MilpEngine;
use saturn::trials::{profile_analytic, ProfileTable};
use saturn::util::json::Json;
use saturn::workload::toy_workload;

fn setup(n: usize, cluster: &ClusterSpec)
    -> (Vec<(usize, u64)>, ProfileTable) {
    let jobs = toy_workload(n);
    let lib = default_library();
    let profiles = profile_analytic(&jobs, &lib, cluster);
    let remaining = jobs.iter().map(|j| (j.id, j.total_steps())).collect();
    (remaining, profiles)
}

/// Solve one fleet and reduce to a JSON cell (+ the plan for inspection).
fn run_fleet(bencher: &Bencher, tag: &str, cluster: &ClusterSpec, n: usize)
    -> (Json, SaturnPlan) {
    let (remaining, profiles) = setup(n, cluster);
    let mut plan: Option<SaturnPlan> = None;
    let stats = bencher.run_fn(&format!("{tag}/jobs={n}"), || {
        let (p, _) = solve_joint(&remaining, &profiles, cluster,
                                 SolverMode::Joint);
        plan = Some(p);
    });
    print_stats(&stats);
    let plan = plan.expect("ran at least once");
    let class_jobs: Vec<Json> = (0..cluster.n_classes())
        .map(|ci| {
            Json::num(plan.choices.iter().filter(|p| p.class == ci).count()
                      as f64)
        })
        .collect();
    let class_area: Vec<Json> = (0..cluster.n_classes())
        .map(|ci| Json::num(plan.area_in_class(ci)))
        .collect();
    let cell = Json::obj(vec![
        ("fleet", Json::str(&cluster.fleet_desc())),
        ("gpus", Json::num(cluster.total_gpus() as f64)),
        ("peak_tflops", Json::num(cluster.peak_flops() / 1e12)),
        ("makespan_s", Json::num(plan.predicted_makespan_s)),
        ("lower_bound_s", Json::num(plan.lower_bound_s)),
        ("solve_wall_s", Json::num(stats.mean_s)),
        ("class_jobs", Json::arr(class_jobs.into_iter())),
        ("class_area_s", Json::arr(class_area.into_iter())),
    ]);
    (cell, plan)
}

fn main() {
    let bencher = Bencher::from_env();
    let fast = std::env::var("SATURN_BENCH_FAST").as_deref() == Ok("1");
    let n = if fast { 12 } else { 24 };

    // ------------------------------------------------------------------
    // mixed fleet vs homogeneous-equivalent-FLOPs fleet
    // ------------------------------------------------------------------
    let mixed = ClusterSpec::hetero(2, 2); // 16x A100 + 16x H100
    let a100_peak = saturn::cluster::GpuSpec::a100_40gb().peak_flops;
    // all-A100 fleet of ~equal peak FLOPs, rounded to whole nodes
    let equiv_nodes =
        ((mixed.peak_flops() / a100_peak / 8.0).round() as u32).max(1);
    let homog = ClusterSpec::p4d(equiv_nodes);

    print_header(&format!(
        "mixed fleet [{}] vs homogeneous-equivalent-FLOPs [{}]",
        mixed.fleet_desc(), homog.fleet_desc()));
    let (mixed_cell, mixed_plan) = run_fleet(&bencher, "mixed", &mixed, n);
    let (homog_cell, homog_plan) = run_fleet(&bencher, "homog", &homog, n);
    let flops_ratio = homog.peak_flops() / mixed.peak_flops();
    println!("mixed {:.0}s vs homogeneous {:.0}s (homog fleet carries \
              {:.0}% of the mixed fleet's FLOPs)",
             mixed_plan.predicted_makespan_s,
             homog_plan.predicted_makespan_s, 100.0 * flops_ratio);
    let h100_jobs = mixed_plan.choices.iter().filter(|p| p.class == 1).count();
    println!("mixed plan: {h100_jobs}/{n} jobs on the H100 class, \
              per-class area {:.0}s / {:.0}s",
             mixed_plan.area_in_class(0), mixed_plan.area_in_class(1));
    assert!(h100_jobs > 0,
            "the joint solver left the H100 class completely idle");

    // ------------------------------------------------------------------
    // degenerate single-class probe: per-class path == pooled seed path
    // ------------------------------------------------------------------
    print_header("degenerate all-A100 fleet: per-class vs pooled objective");
    let degen_jobs = 10usize;
    let degen_cluster = ClusterSpec::p4d(2);
    let (remaining, profiles) = setup(degen_jobs, &degen_cluster);
    let (class_obj, class_stats) =
        plan_selection_probe(&remaining, &profiles, &degen_cluster,
                             MilpEngine::Revised)
            .expect("per-class probe solved");
    let (pooled_obj, pooled_stats) =
        plan_selection_probe_pooled(&remaining, &profiles, &degen_cluster,
                                    MilpEngine::Revised)
            .expect("pooled probe solved");
    let rel_delta =
        (class_obj - pooled_obj).abs() / pooled_obj.abs().max(1.0);
    println!("per-class {class_obj:.6}s ({} nodes) vs pooled \
              {pooled_obj:.6}s ({} nodes), rel delta {rel_delta:.2e}",
             class_stats.milp_nodes, pooled_stats.milp_nodes);
    assert!(rel_delta <= 1e-6,
            "degenerate fleet diverged from the homogeneous solver: \
             {class_obj} vs {pooled_obj}");

    // machine-readable perf record
    let out = std::env::var("SATURN_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_hetero.json".to_string());
    let record = Json::obj(vec![
        ("bench", Json::str("hetero")),
        ("jobs", Json::num(n as f64)),
        ("mixed", mixed_cell),
        ("homogeneous", homog_cell),
        ("flops_ratio", Json::num(flops_ratio)),
        ("degenerate", Json::obj(vec![
            ("jobs", Json::num(degen_jobs as f64)),
            ("fleet", Json::str(&degen_cluster.fleet_desc())),
            ("pooled_objective_s", Json::num(pooled_obj)),
            ("class_objective_s", Json::num(class_obj)),
            ("objective_rel_delta", Json::num(rel_delta)),
        ])),
    ]);
    std::fs::write(&out, record.to_string()).expect("writing perf record");
    println!("\nwrote {out}");
}
