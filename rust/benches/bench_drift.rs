//! Bench E15: estimate drift vs online correction (DESIGN.md §4.4).
//!
//! Replays the `bench_online` trace while the TRUTH model drifts away
//! from the profiled estimates (seeded ramps + interference +
//! mis-calibration, `DriftConfig::uniform`), and measures online-Saturn
//! makespan degradation at drift in {0%, 10%, 30%} with the estimate
//! correction ON vs OFF, against an ORACLE-informed planner (reads the
//! frozen truth at every replan — the unreachable upper bound). Each
//! drifted cell is averaged over several drift seeds so a single lucky
//! packing cannot flip the comparison.
//!
//! The drift=0 arm must reproduce `BENCH_online.json`'s online-saturn
//! makespan within 1e-6 — the refactor is a strict generalization of
//! the pre-split engine (CI asserts this from the emitted record, and
//! `tests/prop_drift.rs` holds the engine to it bit-for-bit).
//!
//! Emits `BENCH_drift.json` (override with `SATURN_BENCH_OUT`).
//!
//! Run: `cargo bench --bench bench_drift`

use saturn::cluster::ClusterSpec;
use saturn::online::{profile_trace, run_trace_perf, OnlineMetrics};
use saturn::perf::{DriftConfig, PerfModel};
use saturn::saturn::solver::SolverMode;
use saturn::sim::engine::RungConfig;
use saturn::util::json::Json;
use saturn::workload::{generate_trace, ArrivalProcess, Trace, TraceConfig};

const DRIFTS: [f64; 3] = [0.0, 0.10, 0.30];
const DRIFT_SEEDS: [u64; 3] = [7, 8, 9];

struct ArmMean {
    drift: f64,
    correction: bool,
    makespan_s: f64,
    avg_jct_s: f64,
    estimate_mae: f64,
    drift_resolves: f64,
    lp_capped: f64,
    observations: f64,
}

fn run_cell(trace: &Trace, rungs: &RungConfig, cluster: &ClusterSpec,
            mut perf: PerfModel) -> OnlineMetrics {
    let (_, m) = run_trace_perf(trace, Some(rungs), &mut perf, cluster,
                                "online-saturn", SolverMode::Joint, None);
    m
}

/// Mean over drift seeds of one arm; `make` builds the perf model for
/// one seeded drift config (correction on/off or oracle).
fn run_arm(trace: &Trace, rungs: &RungConfig, cluster: &ClusterSpec,
           drift: f64, correction: bool,
           make: impl Fn(DriftConfig) -> PerfModel) -> ArmMean {
    let mut ms = Vec::new();
    for &ds in &DRIFT_SEEDS {
        let cfg = if drift > 0.0 {
            DriftConfig::uniform(ds, drift)
        } else {
            DriftConfig::none()
        };
        ms.push(run_cell(trace, rungs, cluster, make(cfg)));
        if drift == 0.0 {
            break; // zero drift is seed-independent; one run suffices
        }
    }
    let n = ms.len() as f64;
    ArmMean {
        drift,
        correction,
        makespan_s: ms.iter().map(|m| m.makespan_s).sum::<f64>() / n,
        avg_jct_s: ms.iter().map(|m| m.avg_jct_s).sum::<f64>() / n,
        estimate_mae: ms.iter().map(|m| m.estimate_mae).sum::<f64>() / n,
        drift_resolves: ms
            .iter()
            .map(|m| m.drift_resolves.unwrap_or(0) as f64)
            .sum::<f64>()
            / n,
        lp_capped: ms.iter().map(|m| m.lp_capped as f64).sum::<f64>() / n,
        observations: ms.iter().map(|m| m.observations as f64).sum::<f64>()
            / n,
    }
}

fn arm_json(a: &ArmMean) -> Json {
    Json::obj(vec![
        ("drift", Json::num(a.drift)),
        ("correction", Json::Bool(a.correction)),
        ("seeds", Json::num(if a.drift == 0.0 {
            1.0
        } else {
            DRIFT_SEEDS.len() as f64
        })),
        ("makespan_s_mean", Json::num(a.makespan_s)),
        ("avg_jct_s_mean", Json::num(a.avg_jct_s)),
        ("estimate_mae_mean", Json::num(a.estimate_mae)),
        ("drift_resolves_mean", Json::num(a.drift_resolves)),
        ("lp_capped_mean", Json::num(a.lp_capped)),
        ("observations_mean", Json::num(a.observations)),
    ])
}

fn main() {
    // EXACTLY the bench_online scenario, so the drift=0 arm is directly
    // comparable to BENCH_online.json's online-saturn row
    let cfg = TraceConfig {
        seed: 42,
        multijobs: 6,
        process: ArrivalProcess::Poisson { rate_per_hour: 2.0 },
        grid_lrs: 2,
        grid_batches: 2,
        epochs: 1,
        tenants: 2,
        deadline_slack_s: Some(24.0 * 3600.0),
        burst_stagger_s: 0.0,
    };
    let trace = generate_trace(&cfg);
    let cluster = ClusterSpec::p4d(1);
    let profiles = profile_trace(&trace, &cluster);
    let rungs = RungConfig::halving();

    println!("=== drift bench: {} jobs / {} multi-jobs, drift in \
              {DRIFTS:?}, {} drift seed(s) ===",
             trace.jobs.len(), trace.groups, DRIFT_SEEDS.len());

    let mut arms: Vec<ArmMean> = Vec::new();
    for &d in &DRIFTS {
        for &corr in &[true, false] {
            arms.push(run_arm(&trace, &rungs, &cluster, d, corr, |cfg| {
                PerfModel::with_drift(&profiles, cfg, corr)
            }));
        }
    }
    let oracle: Vec<ArmMean> = DRIFTS
        .iter()
        .map(|&d| {
            run_arm(&trace, &rungs, &cluster, d, true, |cfg| {
                PerfModel::oracle(&profiles, cfg)
            })
        })
        .collect();

    println!("{:<8} {:>12} {:>14} {:>14} {:>12} {:>10}", "drift",
             "oracle(h)", "corrected(h)", "frozen(h)", "degrade(%)",
             "|ln err|");
    for (i, &d) in DRIFTS.iter().enumerate() {
        let on = &arms[2 * i];
        let off = &arms[2 * i + 1];
        let orc = &oracle[i];
        println!("{:<8.2} {:>12.3} {:>14.3} {:>14.3} {:>12.2} {:>10.4}",
                 d, orc.makespan_s / 3600.0, on.makespan_s / 3600.0,
                 off.makespan_s / 3600.0,
                 100.0 * (on.makespan_s / orc.makespan_s - 1.0),
                 on.estimate_mae);
        if d >= 0.10 {
            println!("  correction gain at {:.0}% drift: {:.2}% makespan \
                      ({:.0} drift re-solve(s)/run)",
                     d * 100.0,
                     100.0 * (off.makespan_s / on.makespan_s - 1.0),
                     on.drift_resolves);
        }
    }

    // the acceptance probe: drift=0 with correction on IS today's online
    // result (bit-identical engine path; CI re-checks vs BENCH_online)
    let drift0 = &arms[0];
    println!("\ndrift=0 probe: makespan {:.6} h (must match BENCH_online's \
              online-saturn within 1e-6)", drift0.makespan_s / 3600.0);

    let out = std::env::var("SATURN_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_drift.json".to_string());
    let record = Json::obj(vec![
        ("bench", Json::str("drift")),
        ("trace_seed", Json::num(cfg.seed as f64)),
        ("jobs", Json::num(trace.jobs.len() as f64)),
        ("gpus", Json::num(cluster.total_gpus() as f64)),
        ("drifts", Json::arr(DRIFTS.iter().map(|&d| Json::num(d)))),
        ("drift_seeds",
         Json::arr(DRIFT_SEEDS.iter().map(|&s| Json::num(s as f64)))),
        ("arms", Json::arr(arms.iter().map(arm_json))),
        ("oracle", Json::arr(oracle.iter().map(|a| {
            Json::obj(vec![
                ("drift", Json::num(a.drift)),
                ("makespan_s_mean", Json::num(a.makespan_s)),
                ("avg_jct_s_mean", Json::num(a.avg_jct_s)),
            ])
        }))),
        ("drift0_probe", Json::obj(vec![
            ("makespan_s", Json::num(drift0.makespan_s)),
            ("avg_jct_s", Json::num(drift0.avg_jct_s)),
        ])),
    ]);
    std::fs::write(&out, record.to_string()).expect("writing perf record");
    println!("wrote {out}");
}
