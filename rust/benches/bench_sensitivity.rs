//! Bench E13 (ablation): how robust is the Table 2 *shape* to the
//! calibration constants the simulator substitutes for real hardware?
//! Sweeps the library composition (4 vs 5 techniques) and the simulator's
//! checkpoint penalty, reporting the Saturn-vs-CP speedup each time.
//! DESIGN.md §6 claims the orderings are calibration-robust; this is the
//! evidence.
//!
//! Run: `cargo bench --bench bench_sensitivity`

use saturn::cluster::ClusterSpec;
use saturn::exp;
use saturn::parallelism::{default_library, extended_library};
use saturn::sim::engine::SimConfig;
use saturn::trials::profile_analytic;
use saturn::workload::{imagenet_workload, wikitext_workload};

fn speedup(workload: &str, lib: &saturn::parallelism::Library,
           cfg: &SimConfig) -> f64 {
    let jobs = match workload {
        "wikitext" => wikitext_workload(),
        _ => imagenet_workload(),
    };
    let cluster = ClusterSpec::p4d(1);
    let profiles = profile_analytic(&jobs, lib, &cluster);
    let run = |sys: &str| {
        let mut policy = exp::make_policy(sys, 0);
        saturn::sim::engine::simulate(&jobs, &profiles, &cluster,
                                      policy.as_mut(), cfg)
            .makespan_s
    };
    run("current-practice") / run("saturn")
}

fn main() {
    println!("### library-composition ablation (saturn speedup vs CP, 1 node)");
    println!("{:<14} {:>18} {:>22}", "workload", "paper 4-tech lib",
             "+ megatron-tp (5)");
    for w in ["wikitext", "imagenet"] {
        let base = speedup(w, &default_library(), &SimConfig::default());
        let ext = speedup(w, &extended_library(), &SimConfig::default());
        println!("{:<14} {:>17.2}x {:>21.2}x", w, base, ext);
        assert!(base > 1.1, "{w}: saturn advantage vanished ({base:.2}x)");
        // a richer library must never make Saturn worse (it can only add
        // feasible plans) — a key property of the joint formulation
        assert!(ext >= base * 0.98,
                "{w}: extending the library hurt saturn ({base:.2}->{ext:.2})");
    }

    println!("\n### checkpoint-penalty ablation (wikitext, saturn speedup vs CP)");
    println!("{:<14} {:>12}", "penalty (s)", "speedup");
    for penalty in [0.0, 60.0, 600.0, 3600.0] {
        let cfg = SimConfig { checkpoint_penalty_s: penalty,
                              ..Default::default() };
        let s = speedup("wikitext", &default_library(), &cfg);
        println!("{:<14} {:>11.2}x", format!("{penalty:.0}"), s);
        assert!(s > 1.1,
                "speedup not robust to checkpoint penalty {penalty}");
    }
    println!("\n[ok] Table 2 shape is robust across all swept calibrations");
}
