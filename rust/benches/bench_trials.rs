//! Bench E7: Trial-Runner profiling overhead vs job runtimes — the paper's
//! "profiling time tends to be negligible" claim (§2).
//!
//! Run: `cargo bench --bench bench_trials`

use saturn::bench::{print_header, Bencher};
use saturn::cluster::ClusterSpec;
use saturn::parallelism::default_library;
use saturn::trials::profile_analytic;
use saturn::workload::{imagenet_workload, wikitext_workload};

fn main() {
    let bencher = Bencher::from_env();
    let lib = default_library();

    print_header("trial-runner wall time (analytic mode)");
    for (name, jobs) in [("wikitext", wikitext_workload()),
                         ("imagenet", imagenet_workload())] {
        for nodes in [1u32, 2] {
            let cluster = ClusterSpec::p4d(nodes);
            let s = bencher.run_fn(&format!("profile/{name}/{nodes}-node"),
                                   || {
                let t = profile_analytic(&jobs, &lib, &cluster);
                std::hint::black_box(t.len());
            });
            saturn::bench::print_stats(&s);
        }
    }

    println!("\n### simulated on-cluster probe cost vs workload runtime");
    println!("{:<14} {:>16} {:>14} {:>16} {:>10}", "workload",
             "gpu-time (s)", "wall (s)", "cp makespan (s)", "fraction");
    for (name, jobs) in [("wikitext", wikitext_workload()),
                         ("imagenet", imagenet_workload())] {
        let cluster = ClusterSpec::p4d(1);
        let profiles = profile_analytic(&jobs, &lib, &cluster);
        let cell = saturn::exp::run_cell_with(&jobs, &profiles, &cluster,
                                              "current-practice", 0);
        // probes for distinct (job, tech, g) combos run cluster-parallel
        // before training starts; profiling_cost_s sums them sequentially
        let wall = profiles.profiling_cost_s / cluster.total_gpus() as f64;
        let frac = wall / cell.result.makespan_s;
        println!("{:<14} {:>16.1} {:>14.1} {:>16.0} {:>9.2}%", name,
                 profiles.profiling_cost_s, wall, cell.result.makespan_s,
                 frac * 100.0);
        assert!(frac < 0.02, "profiling must be negligible (paper §2)");
    }
    println!("\n[ok] cluster-parallel probe cost < 2% of makespan on both \
              workloads");
}
