//! Bench E13: the online scheduling subsystem — arrival-trace replay
//! (avg/p95 JCT + makespan, online-Saturn vs the online baselines on
//! identical traces) and the warm-vs-cold joint re-solve cost on one
//! identical arrival event. Emits a machine-readable perf record to
//! `BENCH_online.json` (override with `SATURN_BENCH_OUT`).
//!
//! Run: `cargo bench --bench bench_online`

use saturn::bench::{fmt_s, print_header, print_stats, Bencher};
use saturn::cluster::ClusterSpec;
use saturn::exp;
use saturn::online::{profile_trace, run_trace, warm_cold_probe,
                     OnlineMetrics, ONLINE_SYSTEMS};
use saturn::saturn::solver::SolverMode;
use saturn::sim::engine::RungConfig;
use saturn::util::json::Json;
use saturn::workload::{generate_trace, ArrivalProcess, TraceConfig};

fn main() {
    let bencher = Bencher::from_env();
    let cfg = TraceConfig {
        seed: 42,
        multijobs: 6,
        process: ArrivalProcess::Poisson { rate_per_hour: 2.0 },
        grid_lrs: 2,
        grid_batches: 2,
        epochs: 1,
        tenants: 2,
        deadline_slack_s: Some(24.0 * 3600.0),
        burst_stagger_s: 0.0,
    };
    let trace = generate_trace(&cfg);
    let cluster = ClusterSpec::p4d(1);
    let profiles = profile_trace(&trace, &cluster);
    let rungs = RungConfig::halving();

    print_header(&format!(
        "online trace replay ({} jobs / {} multi-jobs, rungs {:?})",
        trace.jobs.len(), trace.groups, rungs.fractions));
    let mut metrics: Vec<OnlineMetrics> = Vec::new();
    let mut replay_wall = Vec::new();
    for sys in ONLINE_SYSTEMS {
        let mut last: Option<OnlineMetrics> = None;
        let stats = bencher.run_fn(sys, || {
            let (_, m) = run_trace(&trace, Some(&rungs), &profiles, &cluster,
                                   sys, SolverMode::Joint);
            last = Some(m);
        });
        print_stats(&stats);
        replay_wall.push(stats.mean_s);
        metrics.push(last.expect("ran at least once"));
    }
    print!("\n{}", exp::format_online_row(&metrics));

    // headline: JCT comparison vs both baselines
    let sat = &metrics[2];
    for m in &metrics[..2] {
        println!("online-saturn vs {}: {:.2}x avg JCT, {:.2}x p95 JCT",
                 m.system, m.avg_jct_s / sat.avg_jct_s,
                 m.p95_jct_s / sat.p95_jct_s);
    }
    // per-re-solve wall time across the replay (the online decision
    // latency bench_incremental stresses at scale)
    println!("online-saturn solve wall: p50 {}, p99 {} over {} re-solve(s)",
             fmt_s(sat.solve_p50_s.unwrap_or(0.0)),
             fmt_s(sat.solve_p99_s.unwrap_or(0.0)),
             sat.solves.unwrap_or(0));

    print_header("warm vs cold joint re-solve (same arrival event)");
    // best-of-N wall times: the node counts are deterministic, the wall
    // times are min-filtered to suppress scheduler noise
    let reps = if std::env::var("SATURN_BENCH_FAST").as_deref() == Ok("1") {
        3
    } else {
        15
    };
    let mut probe = warm_cold_probe(&trace, &profiles, &cluster);
    let (mut cold_wall, mut warm_wall) = (probe.cold.wall_s, probe.warm.wall_s);
    for _ in 1..reps {
        let p = warm_cold_probe(&trace, &profiles, &cluster);
        cold_wall = cold_wall.min(p.cold.wall_s);
        warm_wall = warm_wall.min(p.warm.wall_s);
        probe = p;
    }
    println!("{:<44} {:>10} {:>10} nodes", "re-solve", "wall", "B&B");
    println!("{:<44} {:>10} {:>10}", "cold", fmt_s(cold_wall),
             probe.cold.milp_nodes);
    println!("{:<44} {:>10} {:>10}", "warm (prev-plan incumbent)",
             fmt_s(warm_wall), probe.warm.milp_nodes);
    println!("warm speedup: {:.2}x wall, {:.2}x nodes \
              (plan quality {:.1}s vs {:.1}s)",
             cold_wall / warm_wall.max(1e-12),
             probe.cold.milp_nodes as f64
                 / probe.warm.milp_nodes.max(1) as f64,
             probe.warm_makespan_s, probe.cold_makespan_s);

    // machine-readable perf record
    let out = std::env::var("SATURN_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_online.json".to_string());
    let record = Json::obj(vec![
        ("bench", Json::str("online")),
        ("seed", Json::num(cfg.seed as f64)),
        ("multijobs", Json::num(cfg.multijobs as f64)),
        ("jobs", Json::num(trace.jobs.len() as f64)),
        ("gpus", Json::num(cluster.total_gpus() as f64)),
        ("rung_fractions",
         Json::arr(rungs.fractions.iter().map(|&f| Json::num(f)))),
        ("kill_fraction", Json::num(rungs.kill_fraction)),
        ("systems", Json::arr(metrics.iter().map(|m| m.to_json()))),
        ("replay_wall_s",
         Json::arr(replay_wall.iter().map(|&w| Json::num(w)))),
        ("saturn_solve_p50_s",
         Json::num(metrics[2].solve_p50_s.unwrap_or(0.0))),
        ("saturn_solve_p99_s",
         Json::num(metrics[2].solve_p99_s.unwrap_or(0.0))),
        ("warm_cold", Json::obj(vec![
            ("jobs_before", Json::num(probe.jobs_before as f64)),
            ("jobs_after", Json::num(probe.jobs_after as f64)),
            ("cold_wall_s", Json::num(cold_wall)),
            ("warm_wall_s", Json::num(warm_wall)),
            ("cold_nodes", Json::num(probe.cold.milp_nodes as f64)),
            ("warm_nodes", Json::num(probe.warm.milp_nodes as f64)),
            ("cold_makespan_s", Json::num(probe.cold_makespan_s)),
            ("warm_makespan_s", Json::num(probe.warm_makespan_s)),
        ])),
    ]);
    std::fs::write(&out, record.to_string()).expect("writing perf record");
    println!("\nwrote {out}");
}
