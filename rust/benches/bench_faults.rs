//! Bench E16: failure-aware vs failure-blind scheduling (DESIGN.md
//! §4.7).
//!
//! Replays the `bench_online` trace while a seeded [`FaultConfig`]
//! kills nodes (exponential MTBF per node, transient repairs + flaky
//! hosts) and crashes jobs, rolling victims back to their last periodic
//! checkpoint. Sweeps per-node MTBF in {off, 2 h, 8 h} and compares
//! online-Saturn with failure awareness ON (failure-triggered re-solves
//! against the degraded fleet) vs OFF (the stale-plan ablation) on
//! goodput, lost work, and recovery latency. Each faulted cell is
//! averaged over several fault seeds so one lucky outage schedule
//! cannot flip the comparison.
//!
//! The zero-fault probe runs the fault entry point on the exact
//! `bench_online` scenario and must reproduce `BENCH_online.json`'s
//! online-saturn makespan within 1e-6 — the fault layer is a strict
//! generalization of the fault-free engine (asserted bitwise here, and
//! `tests/prop_faults.rs` holds every system to it bit-for-bit).
//!
//! Emits `BENCH_faults.json` (override with `SATURN_BENCH_OUT`).
//!
//! Run: `cargo bench --bench bench_faults`

use saturn::cluster::ClusterSpec;
use saturn::faults::FaultConfig;
use saturn::online::{profile_trace, run_trace_faults, run_trace_perf,
                     OnlineMetrics};
use saturn::perf::PerfModel;
use saturn::saturn::solver::SolverMode;
use saturn::sim::engine::{RungConfig, SimConfig};
use saturn::util::json::Json;
use saturn::workload::{generate_trace, ArrivalProcess, Trace, TraceConfig};

const MTBFS: [f64; 3] = [0.0, 2.0, 8.0];
const FAULT_SEEDS: [u64; 3] = [1, 2, 3];
const CHECKPOINT_S: f64 = 900.0;

struct ArmMean {
    mtbf_hours: f64,
    aware: bool,
    seeds: usize,
    makespan_s: f64,
    avg_jct_s: f64,
    goodput: f64,
    failures: f64,
    fault_preemptions: f64,
    lost_work_gpu_s: f64,
    mean_recovery_s: f64,
    solver_fallbacks: f64,
}

fn run_cell(trace: &Trace, rungs: &RungConfig, cluster: &ClusterSpec,
            mut perf: PerfModel, mtbf_hours: f64, fault_seed: u64,
            aware: bool) -> OnlineMetrics {
    let cfg = SimConfig {
        faults: if mtbf_hours > 0.0 {
            FaultConfig::uniform(fault_seed, mtbf_hours)
        } else {
            FaultConfig::none()
        },
        checkpoint_interval_s: CHECKPOINT_S,
        ..SimConfig::default()
    };
    let (_, m) = run_trace_faults(trace, Some(rungs), &mut perf, cluster,
                                  SolverMode::Joint, &cfg, aware);
    m
}

/// Mean over fault seeds of one (MTBF, awareness) arm.
fn run_arm(trace: &Trace, rungs: &RungConfig, cluster: &ClusterSpec,
           profiles: &saturn::trials::ProfileTable, seeds: &[u64],
           mtbf_hours: f64, aware: bool) -> ArmMean {
    let mut ms = Vec::new();
    for &fs in seeds {
        ms.push(run_cell(trace, rungs, cluster, PerfModel::exact(profiles),
                         mtbf_hours, fs, aware));
        if mtbf_hours == 0.0 {
            break; // zero faults is seed-independent; one run suffices
        }
    }
    let n = ms.len() as f64;
    ArmMean {
        mtbf_hours,
        aware,
        seeds: ms.len(),
        makespan_s: ms.iter().map(|m| m.makespan_s).sum::<f64>() / n,
        avg_jct_s: ms.iter().map(|m| m.avg_jct_s).sum::<f64>() / n,
        goodput: ms.iter().map(|m| m.goodput).sum::<f64>() / n,
        failures: ms.iter().map(|m| m.failures as f64).sum::<f64>() / n,
        fault_preemptions: ms
            .iter()
            .map(|m| m.fault_preemptions as f64)
            .sum::<f64>()
            / n,
        lost_work_gpu_s: ms.iter().map(|m| m.lost_work_gpu_s).sum::<f64>()
            / n,
        mean_recovery_s: ms.iter().map(|m| m.mean_recovery_s).sum::<f64>()
            / n,
        solver_fallbacks: ms
            .iter()
            .map(|m| m.solver_fallbacks.unwrap_or(0) as f64)
            .sum::<f64>()
            / n,
    }
}

fn arm_json(a: &ArmMean) -> Json {
    Json::obj(vec![
        ("mtbf_hours", Json::num(a.mtbf_hours)),
        ("failure_aware", Json::Bool(a.aware)),
        ("seeds", Json::num(a.seeds as f64)),
        ("makespan_s_mean", Json::num(a.makespan_s)),
        ("avg_jct_s_mean", Json::num(a.avg_jct_s)),
        ("goodput_mean", Json::num(a.goodput)),
        ("failures_mean", Json::num(a.failures)),
        ("fault_preemptions_mean", Json::num(a.fault_preemptions)),
        ("lost_work_gpu_s_mean", Json::num(a.lost_work_gpu_s)),
        ("mean_recovery_s_mean", Json::num(a.mean_recovery_s)),
        ("solver_fallbacks_mean", Json::num(a.solver_fallbacks)),
    ])
}

fn main() {
    // EXACTLY the bench_online scenario, so the zero-fault probe is
    // directly comparable to BENCH_online.json's online-saturn row
    let cfg = TraceConfig {
        seed: 42,
        multijobs: 6,
        process: ArrivalProcess::Poisson { rate_per_hour: 2.0 },
        grid_lrs: 2,
        grid_batches: 2,
        epochs: 1,
        tenants: 2,
        deadline_slack_s: Some(24.0 * 3600.0),
        burst_stagger_s: 0.0,
    };
    let trace = generate_trace(&cfg);
    let rungs = RungConfig::halving();
    let fast = std::env::var("SATURN_BENCH_FAST").as_deref() == Ok("1");
    let seeds: &[u64] = if fast { &FAULT_SEEDS[..1] } else { &FAULT_SEEDS };

    // zero-fault probe on the bench_online cluster: the fault entry
    // point must be a bitwise no-op when faults are off
    let probe_cluster = ClusterSpec::p4d(1);
    let probe_profiles = profile_trace(&trace, &probe_cluster);
    let mut base_perf = PerfModel::exact(&probe_profiles);
    let (_, base) = run_trace_perf(&trace, Some(&rungs), &mut base_perf,
                                   &probe_cluster, "online-saturn",
                                   SolverMode::Joint, None);
    let probe = run_cell(&trace, &rungs, &probe_cluster,
                         PerfModel::exact(&probe_profiles), 0.0, 0, true);
    assert_eq!(probe.makespan_s.to_bits(), base.makespan_s.to_bits(),
               "zero-fault run diverged from the fault-free engine");
    assert_eq!(probe.goodput.to_bits(),
               probe.gpu_utilization.to_bits(),
               "goodput must equal utilization without faults");

    // the fault sweep runs on two nodes so a node death degrades the
    // fleet instead of erasing it
    let cluster = ClusterSpec::p4d(2);
    let profiles = profile_trace(&trace, &cluster);

    println!("=== fault bench: {} jobs / {} multi-jobs, per-node MTBF in \
              {MTBFS:?} h, {} fault seed(s), checkpoint every {:.0} s ===",
             trace.jobs.len(), trace.groups, seeds.len(), CHECKPOINT_S);

    let mut arms: Vec<ArmMean> = Vec::new();
    for &mtbf in &MTBFS {
        for &aware in &[true, false] {
            arms.push(run_arm(&trace, &rungs, &cluster, &profiles, seeds,
                              mtbf, aware));
        }
    }

    println!("{:<10} {:>12} {:>12} {:>10} {:>10} {:>12} {:>12}",
             "mtbf(h)", "aware good.", "blind good.", "gain(%)",
             "failures", "lost(gpu-h)", "recovery(s)");
    for (i, &mtbf) in MTBFS.iter().enumerate() {
        let on = &arms[2 * i];
        let off = &arms[2 * i + 1];
        println!("{:<10.1} {:>12.4} {:>12.4} {:>10.2} {:>10.1} {:>12.2} \
                  {:>12.0}",
                 mtbf, on.goodput, off.goodput,
                 100.0 * (on.goodput / off.goodput.max(1e-12) - 1.0),
                 on.failures, on.lost_work_gpu_s / 3600.0,
                 on.mean_recovery_s);
    }

    println!("\nzero-fault probe: makespan {:.6} h (must match \
              BENCH_online's online-saturn within 1e-6)",
             probe.makespan_s / 3600.0);

    let out = std::env::var("SATURN_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_faults.json".to_string());
    let record = Json::obj(vec![
        ("bench", Json::str("faults")),
        ("trace_seed", Json::num(cfg.seed as f64)),
        ("jobs", Json::num(trace.jobs.len() as f64)),
        ("gpus", Json::num(cluster.total_gpus() as f64)),
        ("mtbf_hours", Json::arr(MTBFS.iter().map(|&m| Json::num(m)))),
        ("fault_seeds",
         Json::arr(seeds.iter().map(|&s| Json::num(s as f64)))),
        ("checkpoint_interval_s", Json::num(CHECKPOINT_S)),
        ("arms", Json::arr(arms.iter().map(arm_json))),
        ("zero_probe", Json::obj(vec![
            ("makespan_s", Json::num(probe.makespan_s)),
            ("avg_jct_s", Json::num(probe.avg_jct_s)),
            ("goodput", Json::num(probe.goodput)),
        ])),
    ]);
    std::fs::write(&out, record.to_string()).expect("writing perf record");
    println!("wrote {out}");
}
