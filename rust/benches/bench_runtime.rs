//! Bench E11/E12: the PJRT hot path — real train-step latency/throughput
//! per AOT artifact, plus runtime dispatch overhead (host<->device literal
//! traffic vs pure execution). Feeds EXPERIMENTS.md §Perf (L3 runtime).
//!
//! Run: `cargo bench --bench bench_runtime`

use std::sync::Arc;

use saturn::bench::{print_header, print_stats, Bencher};
use saturn::data::TokenStream;
use saturn::runtime::{Engine, Manifest, Trainer};

fn main() {
    let engine = Arc::new(Engine::cpu().expect("PJRT CPU client"));
    let manifest = Manifest::load_default().expect("run `make artifacts`");
    let bencher = Bencher::from_env();
    println!("platform: {}", engine.platform());

    print_header("train-step latency (real PJRT execution)");
    for a in manifest.artifacts.clone() {
        if a.kind != "train" {
            continue;
        }
        let batch = a.batch.unwrap();
        let mut t = Trainer::new(engine.clone(), &manifest, &a.model, batch, 0)
            .expect("trainer");
        let mut stream = TokenStream::new(7, a.vocab);
        let b = batch as usize;
        let s = a.seq as usize;
        // warmup / compile
        let toks = stream.batch(b, s);
        t.step_tokens(1e-3, &toks).unwrap();
        let stats = bencher.run_fn(&a.name, || {
            let toks = stream.batch(b, s);
            t.step_tokens(1e-3, &toks).unwrap();
        });
        print_stats(&stats);
        let tokens = (b * s) as f64;
        println!(
            "{:<44} {:>10.0} tok/s {:>12.2} MFLOP/s/step-flops",
            format!("  throughput/{}", a.name),
            stats.throughput(tokens),
            a.flops_per_step / stats.mean_s / 1e6
        );
    }

    print_header("eval-step latency");
    for a in manifest.artifacts.clone() {
        if a.kind != "eval" {
            continue;
        }
        let exe = engine.load_artifact(&a).unwrap();
        let p = a.padded_params;
        let flat = xla::Literal::vec1(&vec![0.01f32; p]);
        let b = a.batch.unwrap() as usize;
        let toks = xla::Literal::vec1(&vec![1i32; b * a.seq as usize])
            .reshape(&[b as i64, a.seq as i64])
            .unwrap();
        let stats = bencher.run_fn(&a.name, || {
            let out = engine.run(&exe, &[flat.clone(), toks.clone()]).unwrap();
            std::hint::black_box(out.len());
        });
        print_stats(&stats);
    }

    print_header("dispatch overhead: init artifact (tiny state transfer)");
    let init = manifest.init("tiny").unwrap();
    let exe = engine.load_artifact(init).unwrap();
    let stats = bencher.run_fn("init_tiny (execute+fetch)", || {
        let out = engine.run(&exe, &[xla::Literal::scalar(0i32)]).unwrap();
        std::hint::black_box(out.len());
    });
    print_stats(&stats);
}
