//! Bench E1/E2/E6: regenerate paper Table 2 — both workloads, 1 and 2
//! nodes, all five systems — and print measured-vs-paper side by side plus
//! the §3 speedup/reduction headline.
//!
//! Run: `cargo bench --bench bench_table2`
//! (absolute hours differ from the authors' physical testbed; the checked
//! property is the *shape*: ordering, speedup factors, crossovers.)

use saturn::exp;

fn main() {
    let seed = 0;
    let mut all_ok = true;
    for workload in ["wikitext", "imagenet"] {
        let t0 = std::time::Instant::now();
        let cells = exp::run_row(workload, seed);
        print!("{}", exp::format_row(workload, &cells));
        println!("(row generated in {:.2}s)\n", t0.elapsed().as_secs_f64());

        // shape assertions (same ones EXPERIMENTS.md reports)
        let m = |i: usize| (cells[i].0.makespan_h, cells[i].1.makespan_h);
        let (cp1, cp2) = m(0);
        let (rnd1, _) = m(1);
        let (opt1, _) = m(2);
        let (od1, od2) = m(3);
        let (sat1, sat2) = m(4);
        let best1 = od1.min(opt1).min(cp1).min(rnd1);
        let checks: Vec<(&str, bool)> = vec![
            ("saturn fastest (1-node)", sat1 < best1),
            ("saturn best-or-within-5% (2-node)", sat2 < od2.min(cp2) * 1.05),
            ("random slowest-or-near (1-node)", rnd1 > cp1 * 0.9),
            ("optimus-dynamic beats optimus", od1 <= opt1 * 1.02),
            ("speedup in paper-ish band 1.25-2.6x (1-node)",
             (1.25..2.6).contains(&(cp1 / sat1))),
            ("2 nodes roughly halve saturn", sat2 < sat1 * 0.7),
        ];
        for (name, ok) in checks {
            println!("  [{}] {name}", if ok { "ok" } else { "FAIL" });
            all_ok &= ok;
        }
        println!();
    }
    if !all_ok {
        println!("WARNING: some Table 2 shape checks failed");
        std::process::exit(1);
    }
}
