//! Bench E18: incremental re-solve decision latency (DESIGN.md §4.9) —
//! a staggered HPO-burst arrival trace (64 job siblings per burst)
//! replayed through online-Saturn under three arms:
//!
//!   * `full`            — historical behaviour: every event re-solves
//!                         the joint problem from scratch
//!   * `delta`           — `--incremental on`: retained column pools,
//!                         duals and master basis across events
//!   * `delta_coalesce`  — incremental plus the event-coalescing
//!                         debounce window folding each staggered burst
//!                         into one delta re-solve
//!
//! Reports per-event decision latency and per-solve wall p50/p99 for
//! each arm, checks the tight-gap parity of the seeded probe against
//! the from-scratch probe (<= 1e-6 relative), and emits a
//! machine-readable record to `BENCH_incremental.json` (override with
//! `SATURN_BENCH_OUT`). `SATURN_BENCH_FAST=1` runs the 256-job point
//! only.
//!
//! Run: `cargo bench --bench bench_incremental`

use saturn::bench::{fmt_s, print_header};
use saturn::cluster::ClusterSpec;
use saturn::objective::Objective;
use saturn::obs::trace::Tracer;
use saturn::online::{profile_trace, run_trace_knobs, OnlineKnobs,
                     OnlineMetrics};
use saturn::perf::PerfModel;
use saturn::saturn::solver::{plan_selection_probe, solve_joint_budgeted,
                             SolveBudget, SolverMode};
use saturn::saturn::IncrementalSolver;
use saturn::sim::engine::{RungConfig, SimConfig};
use saturn::solver::milp::MilpEngine;
use saturn::util::json::Json;
use saturn::workload::{generate_trace, ArrivalProcess, Trace, TraceConfig};

/// Jobs per burst = burst multi-jobs x the 2x2 grid.
const BURST_MULTIJOBS: usize = 16;
const GRID_JOBS: usize = 4;
const STAGGER_S: f64 = 1.0;
const COALESCE_WINDOW_S: f64 = 30.0;

fn burst_trace(jobs: usize) -> Trace {
    generate_trace(&TraceConfig {
        seed: 42,
        multijobs: jobs / GRID_JOBS,
        process: ArrivalProcess::Burst {
            rate_per_hour: 2.0,
            burst_size: BURST_MULTIJOBS,
        },
        grid_lrs: 2,
        grid_batches: 2,
        epochs: 1,
        tenants: 2,
        deadline_slack_s: None,
        burst_stagger_s: STAGGER_S,
    })
}

struct Arm {
    name: &'static str,
    knobs: OnlineKnobs,
    coalesce_window_s: f64,
}

fn arms() -> Vec<Arm> {
    let delta = OnlineKnobs { incremental: true, ..OnlineKnobs::default() };
    vec![
        Arm { name: "full", knobs: OnlineKnobs::default(),
              coalesce_window_s: 0.0 },
        Arm { name: "delta", knobs: delta, coalesce_window_s: 0.0 },
        Arm { name: "delta_coalesce", knobs: delta,
              coalesce_window_s: COALESCE_WINDOW_S },
    ]
}

struct ArmResult {
    name: &'static str,
    replay_wall_s: f64,
    metrics: OnlineMetrics,
    coalesced: usize,
}

fn run_arm(arm: &Arm, trace: &Trace, cluster: &ClusterSpec,
           rungs: &RungConfig) -> ArmResult {
    let profiles = profile_trace(trace, cluster);
    let mut perf = PerfModel::exact(&profiles);
    let cfg = SimConfig {
        coalesce_window_s: arm.coalesce_window_s,
        ..SimConfig::default()
    };
    let t0 = std::time::Instant::now();
    let (result, metrics) = run_trace_knobs(
        trace, Some(rungs), &mut perf, cluster, "online-saturn",
        SolverMode::Sharded { cell_size: 64 }, None, &cfg, arm.knobs);
    let replay_wall_s = t0.elapsed().as_secs_f64();
    assert_eq!(metrics.completed + metrics.early_stopped, trace.jobs.len(),
               "arm {} lost jobs", arm.name);
    ArmResult {
        name: arm.name,
        replay_wall_s,
        metrics,
        coalesced: result.coalesced_events,
    }
}

/// Tight-gap parity: seed an [`IncrementalSolver`] from a full solve,
/// replay a departure as a delta, then compare the state-seeded
/// column-generation probe against the from-scratch probe. Exactness
/// comes from the reduced-cost widening pass, so the relative error
/// must sit inside the 1e-6 convergence gap.
fn parity_check(trace: &Trace, cluster: &ClusterSpec) -> f64 {
    let profiles = profile_trace(trace, cluster);
    let roster: Vec<(usize, u64)> = trace.jobs.iter().take(64)
        .map(|o| (o.job.id, o.job.total_steps()))
        .collect();
    let (plan, _) = solve_joint_budgeted(
        &roster, &profiles, cluster, SolverMode::Sharded { cell_size: 64 },
        1.0, None, Objective::Makespan, &[], &Tracer::off(), None,
        SolveBudget::default());
    let mut inc = IncrementalSolver::new();
    inc.note_full(&roster, &plan, Objective::Makespan, None);
    // one grid departs (6 % churn) and the next event goes delta
    let after = &roster[..roster.len() - GRID_JOBS];
    let delta = inc.solve_delta(after, &profiles, cluster, 1.0, None,
                                Objective::Makespan, &[], &Tracer::off(),
                                None, SolveBudget::default());
    assert!(delta.is_some(), "delta re-solve failed on a plain departure");
    let (seeded, _) = inc.parity_probe(after, &profiles, cluster)
        .expect("seeded parity probe failed");
    let (scratch, _) = plan_selection_probe(after, &profiles, cluster,
                                            MilpEngine::Revised)
        .expect("from-scratch probe failed");
    let rel = (seeded - scratch).abs() / scratch.abs().max(1.0);
    assert!(rel <= 1e-6,
            "seeded probe {seeded} vs scratch probe {scratch}: rel {rel}");
    rel
}

fn main() {
    let fast = std::env::var("SATURN_BENCH_FAST").as_deref() == Ok("1");
    let sizes: &[usize] = if fast { &[256] } else { &[256, 512] };
    let cluster = ClusterSpec::p4d(4);
    let rungs = RungConfig::halving();

    print_header("incremental re-solve parity (seeded vs from-scratch)");
    let parity_rel = parity_check(&burst_trace(64), &cluster);
    println!("tight-gap relative error: {parity_rel:.3e} (bound 1e-6)");

    let mut size_records = Vec::new();
    for &n in sizes {
        let trace = burst_trace(n);
        print_header(&format!(
            "burst trace replay ({} jobs, {} multi-jobs, {} siblings/burst, \
             stagger {STAGGER_S:.0} s)",
            trace.jobs.len(), trace.groups, BURST_MULTIJOBS * GRID_JOBS));
        println!("{:<16} {:>10} {:>10} {:>10} {:>10} {:>7} {:>7} {:>9}",
                 "arm", "dec p50", "dec p99", "solve p50", "solve p99",
                 "delta", "full", "coalesced");
        let mut results = Vec::new();
        for arm in arms() {
            let r = run_arm(&arm, &trace, &cluster, &rungs);
            let m = &r.metrics;
            println!("{:<16} {:>10} {:>10} {:>10} {:>10} {:>7} {:>7} {:>9}",
                     r.name,
                     fmt_s(m.decision_p50_s), fmt_s(m.decision_p99_s),
                     fmt_s(m.solve_p50_s.unwrap_or(0.0)),
                     fmt_s(m.solve_p99_s.unwrap_or(0.0)),
                     m.delta_resolves.unwrap_or(0),
                     m.full_resolves.unwrap_or(0),
                     r.coalesced);
            results.push(r);
        }
        let full_p99 = results[0].metrics.solve_p99_s.unwrap_or(0.0);
        let delta_p99 = results[1].metrics.solve_p99_s.unwrap_or(0.0);
        let co_p99 = results[2].metrics.solve_p99_s.unwrap_or(0.0);
        let delta_speedup = full_p99 / delta_p99.max(1e-12);
        let co_speedup = full_p99 / co_p99.max(1e-12);
        println!("p99 speedup vs full: delta {delta_speedup:.2}x, \
                  delta+coalesce {co_speedup:.2}x");
        assert!(results[1].metrics.delta_resolves.unwrap_or(0) > 0,
                "delta arm never took the delta path at n={n}");
        assert!(results[2].coalesced > 0,
                "coalesce arm never folded an event at n={n}");
        if n >= 256 {
            assert!(delta_p99 <= full_p99,
                    "delta p99 {delta_p99} above full p99 {full_p99} at \
                     n={n}");
            assert!(co_p99 <= full_p99,
                    "delta+coalesce p99 {co_p99} above full p99 {full_p99} \
                     at n={n}");
        }
        if !fast && n >= 512 {
            assert!(co_speedup >= 2.0,
                    "delta+coalesce p99 speedup {co_speedup:.2}x below the \
                     2x acceptance bar at n={n}");
        }
        size_records.push(Json::obj(vec![
            ("jobs", Json::num(trace.jobs.len() as f64)),
            ("multijobs", Json::num(trace.groups as f64)),
            ("delta_p99_speedup", Json::num(delta_speedup)),
            ("coalesce_p99_speedup", Json::num(co_speedup)),
            ("arms", Json::arr(results.iter().map(|r| {
                let m = &r.metrics;
                Json::obj(vec![
                    ("arm", Json::str(r.name)),
                    ("replay_wall_s", Json::num(r.replay_wall_s)),
                    ("decision_p50_s", Json::num(m.decision_p50_s)),
                    ("decision_p99_s", Json::num(m.decision_p99_s)),
                    ("solve_p50_s",
                     Json::num(m.solve_p50_s.unwrap_or(0.0))),
                    ("solve_p99_s",
                     Json::num(m.solve_p99_s.unwrap_or(0.0))),
                    ("solves",
                     Json::num(m.solves.unwrap_or(0) as f64)),
                    ("delta_resolves",
                     Json::num(m.delta_resolves.unwrap_or(0) as f64)),
                    ("full_resolves",
                     Json::num(m.full_resolves.unwrap_or(0) as f64)),
                    ("budget_exhausted",
                     Json::num(m.budget_exhausted.unwrap_or(0) as f64)),
                    ("coalesced_events", Json::num(r.coalesced as f64)),
                    ("avg_jct_s", Json::num(m.avg_jct_s)),
                    ("makespan_s", Json::num(m.makespan_s)),
                ])
            }))),
        ]));
    }

    let out = std::env::var("SATURN_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_incremental.json".to_string());
    let record = Json::obj(vec![
        ("bench", Json::str("incremental")),
        ("seed", Json::num(42.0)),
        ("burst_siblings", Json::num((BURST_MULTIJOBS * GRID_JOBS) as f64)),
        ("stagger_s", Json::num(STAGGER_S)),
        ("coalesce_window_s", Json::num(COALESCE_WINDOW_S)),
        ("parity_rel_err", Json::num(parity_rel)),
        ("sizes", Json::arr(size_records.into_iter())),
    ]);
    std::fs::write(&out, record.to_string()).expect("writing perf record");
    println!("\nwrote {out}");
}
