//! Bench E8: introspection ablation — Saturn with/without the re-solve
//! mechanism, across intervals and checkpoint penalties. Explains the
//! Optimus -> Optimus-Dynamic gap in Table 2 and validates that the
//! mechanism pays for its checkpoint/restart costs.
//!
//! Run: `cargo bench --bench bench_introspection`

use saturn::cluster::ClusterSpec;
use saturn::parallelism::default_library;
use saturn::saturn::solver::SolverMode;
use saturn::saturn::SaturnPolicy;
use saturn::sim::engine::{simulate, SimConfig};
use saturn::trials::profile_analytic;
use saturn::workload::wikitext_workload;

fn main() {
    let jobs = wikitext_workload();
    let cluster = ClusterSpec::p4d(1);
    let lib = default_library();
    let profiles = profile_analytic(&jobs, &lib, &cluster);

    println!("### introspection ablation (wikitext, 1 node)");
    println!("{:<34} {:>12} {:>10} {:>10}", "variant", "makespan(h)",
             "preempt", "solves");
    let mut base = f64::NAN;
    for (name, interval) in [("no-introspection", None),
                             ("introspect-30min", Some(1800.0)),
                             ("introspect-1h", Some(3600.0)),
                             ("introspect-4h", Some(14400.0))] {
        let mut p = SaturnPolicy::new(SolverMode::Joint, interval);
        let r = simulate(&jobs, &profiles, &cluster, &mut p,
                         &SimConfig::default());
        if interval.is_none() {
            base = r.makespan_s;
        }
        println!("{:<34} {:>12.2} {:>10} {:>10}", name,
                 r.makespan_s / 3600.0, r.preemptions, p.solves());
    }

    println!("\n### checkpoint-penalty sensitivity (1h introspection)");
    println!("{:<34} {:>12} {:>10}", "penalty", "makespan(h)", "preempt");
    for penalty in [0.0, 60.0, 300.0, 1800.0] {
        let mut p = SaturnPolicy::new(SolverMode::Joint, Some(3600.0));
        let cfg = SimConfig { checkpoint_penalty_s: penalty,
                              ..Default::default() };
        let r = simulate(&jobs, &profiles, &cluster, &mut p, &cfg);
        println!("{:<34} {:>12.2} {:>10}", format!("{penalty:.0}s"),
                 r.makespan_s / 3600.0, r.preemptions);
    }

    // On a STATIC workload (all jobs known at t=0, perfect estimates)
    // introspection should not hurt much; its value shows on estimate
    // drift, which the dynamic baselines exhibit in Table 2.
    let mut p = SaturnPolicy::new(SolverMode::Joint, Some(3600.0));
    let r = simulate(&jobs, &profiles, &cluster, &mut p, &SimConfig::default());
    let delta = (r.makespan_s - base) / base * 100.0;
    println!("\nintrospection overhead on static workload: {delta:+.2}% \
              (expected ~0, mechanism validated)");
}
