//! Simulator + solver micro-benchmarks (L3 perf targets in DESIGN.md §8):
//! full-workload simulation wall time per policy, and LP/MILP solve rates.
//!
//! Run: `cargo bench --bench bench_sim`

use saturn::bench::{print_header, print_stats, Bencher};
use saturn::cluster::ClusterSpec;
use saturn::exp;
use saturn::parallelism::default_library;
use saturn::solver::lp::{Cmp, Lp};
use saturn::trials::profile_analytic;
use saturn::util::rng::Rng;
use saturn::workload::wikitext_workload;

fn main() {
    let bencher = Bencher::from_env();

    print_header("full Table-2 cell simulation (12 jobs, 1 node)");
    let jobs = wikitext_workload();
    let cluster = ClusterSpec::p4d(1);
    let lib = default_library();
    let profiles = profile_analytic(&jobs, &lib, &cluster);
    for sys in exp::SYSTEMS {
        let stats = bencher.run_fn(sys, || {
            let c = exp::run_cell_with(&jobs, &profiles, &cluster, sys, 0);
            std::hint::black_box(c.makespan_h);
        });
        print_stats(&stats);
    }

    print_header("trial-runner profiling (4 techs x 4 gpu opts x 12 jobs)");
    let stats = bencher.run_fn("profile_analytic/wikitext", || {
        let t = profile_analytic(&jobs, &lib, &cluster);
        std::hint::black_box(t.len());
    });
    print_stats(&stats);

    print_header("LP simplex solve rate (random dense feasible LPs)");
    let mut rng = Rng::new(11);
    let problems: Vec<Lp> = (0..50)
        .map(|_| {
            let n = 12;
            let m = 10;
            let mut lp = Lp::new(n);
            for j in 0..n {
                lp.set_obj(j, rng.f64() * 2.0 - 1.0);
                lp.bound_le(j, 5.0 + rng.f64() * 5.0);
            }
            for _ in 0..m {
                let coeffs: Vec<(usize, f64)> =
                    (0..n).map(|j| (j, rng.f64())).collect();
                lp.add(coeffs, Cmp::Le, 10.0 + rng.f64() * 20.0);
            }
            lp
        })
        .collect();
    // 10 rows + 12 first-class variable bounds (bounds are not rows
    // since the revised-simplex rebuild)
    let stats = bencher.run_fn("simplex x50 (12 vars, 10 rows)", || {
        for lp in &problems {
            std::hint::black_box(saturn::solver::lp::solve(lp));
        }
    });
    print_stats(&stats);
    println!("{:<44} {:>10.0} solves/s", "  rate",
             50.0 / stats.mean_s);
}
