"""L2: GPT-mini transformer LM in pure JAX, calling the L1 Pallas kernels.

This is the *real* training workload that Saturn's Trial Runner profiles and
the Rust runtime executes. The paper's evaluation models (GPT-2 1.5B, GPT-J
6B, ViT-G, ResNet-200) are represented at paper scale by analytic specs in
``rust/src/models/``; this module provides the runnable counterparts at
CPU-tractable sizes so the whole stack (profile -> solve -> schedule ->
train) executes for real in ``examples/e2e_train.rs``.

Design notes for the Rust boundary:

  * **Flat parameter vector.** All parameters live in one f32 vector
    (padded to a block multiple). Rust never needs to know the pytree:
    ``train_step`` has a fixed 6-tensor signature and the optimizer state is
    two more flat vectors. Unflattening uses static ``lax.slice`` so it
    compiles to views inside the fused step.
  * **Runtime learning rate.** ``lr`` and ``step`` are runtime scalars, so
    ONE compiled artifact serves the entire HPO grid (every LR in Table 1).
    Batch size and sequence length are shape-static, hence per-(model,bs)
    artifacts.
  * Everything lowers through ``aot.py`` to HLO *text* (never proto) --
    see /opt/xla-example/README.md for the 64-bit-id gotcha.

Signatures (all tensors f32 unless noted):

  train_step(flat[P], m[P], v[P], step[], lr[], tokens i32[B,S])
      -> (flat'[P], m'[P], v'[P], loss[])
  eval_step(flat[P], tokens i32[B,S]) -> loss[]
  init_params(seed) -> flat[P]
"""

import dataclasses
import functools
import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from compile.kernels.flash_attention import flash_attention
from compile.kernels.fused_adamw import adamw_sched, adamw_update
from compile.kernels.layernorm import layernorm
from compile.kernels import ref

PAD_MULTIPLE = 2048


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static architecture hyper-parameters of a GPT-mini variant."""
    name: str
    vocab: int = 512
    seq: int = 64
    d_model: int = 128
    n_head: int = 4
    n_layer: int = 2
    use_kernels: bool = True  # False -> pure-jnp reference path (testing)

    @property
    def d_ff(self) -> int:
        return 4 * self.d_model

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_head == 0
        return self.d_model // self.n_head


# CPU-tractable variants. `base` (~29M params) is the "100M-class" stand-in
# for the paper's fine-tuning workloads; `tiny`/`small` keep tests and the
# default e2e example fast on a 2-core CPU testbed.
CONFIGS: Dict[str, ModelConfig] = {
    "tiny": ModelConfig("tiny", seq=64, d_model=128, n_head=4, n_layer=2),
    "small": ModelConfig("small", seq=128, d_model=256, n_head=8, n_layer=4),
    "base": ModelConfig("base", seq=128, d_model=512, n_head=8, n_layer=8),
}


def param_layout(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Fixed (name, shape) order defining the flat vector layout."""
    d, ff = cfg.d_model, cfg.d_ff
    layout = [("wte", (cfg.vocab, d)), ("wpe", (cfg.seq, d))]
    for l in range(cfg.n_layer):
        layout += [
            (f"h{l}.ln1_g", (d,)), (f"h{l}.ln1_b", (d,)),
            (f"h{l}.wqkv", (d, 3 * d)), (f"h{l}.wo", (d, d)),
            (f"h{l}.ln2_g", (d,)), (f"h{l}.ln2_b", (d,)),
            (f"h{l}.w1", (d, ff)), (f"h{l}.b1", (ff,)),
            (f"h{l}.w2", (ff, d)), (f"h{l}.b2", (d,)),
        ]
    layout += [("lnf_g", (d,)), ("lnf_b", (d,))]
    return layout


def param_count(cfg: ModelConfig) -> int:
    return sum(int(math.prod(s)) for _, s in param_layout(cfg))


def padded_param_count(cfg: ModelConfig) -> int:
    n = param_count(cfg)
    return ((n + PAD_MULTIPLE - 1) // PAD_MULTIPLE) * PAD_MULTIPLE


def unflatten(cfg: ModelConfig, flat: jax.Array) -> Dict[str, jax.Array]:
    """Static-slice the flat vector into named parameter views."""
    params = {}
    off = 0
    for name, shape in param_layout(cfg):
        n = int(math.prod(shape))
        params[name] = jax.lax.slice(flat, (off,), (off + n,)).reshape(shape)
        off += n
    return params


def init_params(cfg: ModelConfig, seed) -> jax.Array:
    """GPT-2-style init into the flat (padded) vector. jit-compatible."""
    key = jax.random.PRNGKey(seed)
    chunks = []
    for name, shape in param_layout(cfg):
        key, sub = jax.random.split(key)
        n = int(math.prod(shape))
        base = name.split(".")[-1]
        if base in ("ln1_g", "ln2_g", "lnf_g"):
            chunks.append(jnp.ones((n,), jnp.float32))
        elif base in ("ln1_b", "ln2_b", "lnf_b", "b1", "b2"):
            chunks.append(jnp.zeros((n,), jnp.float32))
        else:
            scale = 0.02
            if base in ("wo", "w2"):  # residual-branch scaling
                scale = 0.02 / math.sqrt(2 * cfg.n_layer)
            chunks.append(scale * jax.random.normal(sub, (n,), jnp.float32))
    flat = jnp.concatenate(chunks)
    pad = padded_param_count(cfg) - flat.shape[0]
    return jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])


def _ln(cfg, x, g, b):
    if cfg.use_kernels:
        return layernorm(x, g, b)
    return ref.layernorm_ref(x, g, b)


def _attn(cfg, x, p, l):
    bsz, seq, d = x.shape
    h, hd = cfg.n_head, cfg.head_dim
    qkv = x @ p[f"h{l}.wqkv"]  # (B,S,3d)
    qkv = qkv.reshape(bsz, seq, 3, h, hd).transpose(2, 0, 3, 1, 4)
    q, k, v = qkv[0], qkv[1], qkv[2]  # (B,H,S,hd)
    if cfg.use_kernels:
        o = flash_attention(q, k, v)
    else:
        o = ref.attention_ref(q, k, v)
    o = o.transpose(0, 2, 1, 3).reshape(bsz, seq, d)
    return o @ p[f"h{l}.wo"]


def _mlp(cfg, x, p, l):
    hdn = jax.nn.gelu(x @ p[f"h{l}.w1"] + p[f"h{l}.b1"])
    return hdn @ p[f"h{l}.w2"] + p[f"h{l}.b2"]


def forward(cfg: ModelConfig, flat: jax.Array, tokens: jax.Array) -> jax.Array:
    """Token ids ``(B,S)`` -> logits ``(B,S,V)`` (embedding tied)."""
    p = unflatten(cfg, flat)
    x = p["wte"][tokens] + p["wpe"][None, :, :]
    for l in range(cfg.n_layer):
        x = x + _attn(cfg, _ln(cfg, x, p[f"h{l}.ln1_g"], p[f"h{l}.ln1_b"]), p, l)
        x = x + _mlp(cfg, _ln(cfg, x, p[f"h{l}.ln2_g"], p[f"h{l}.ln2_b"]), p, l)
    x = _ln(cfg, x, p["lnf_g"], p["lnf_b"])
    return x @ p["wte"].T


def loss_fn(cfg: ModelConfig, flat: jax.Array, tokens: jax.Array) -> jax.Array:
    """Mean next-token cross-entropy over ``(B, S-1)`` positions."""
    logits = forward(cfg, flat, tokens)[:, :-1, :]
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def train_step(cfg: ModelConfig, flat, m, v, step, lr, tokens,
               *, beta1=0.9, beta2=0.999, eps=1e-8, weight_decay=0.01):
    """One fused fwd+bwd+AdamW step. ``step`` is the 1-based step (f32)."""
    loss, grads = jax.value_and_grad(lambda f: loss_fn(cfg, f, tokens))(flat)
    sched = adamw_sched(lr, step, beta1=beta1, beta2=beta2,
                        weight_decay=weight_decay)
    if cfg.use_kernels:
        new_flat, new_m, new_v = adamw_update(
            flat, grads, m, v, sched, beta1=beta1, beta2=beta2, eps=eps)
    else:
        new_flat, new_m, new_v = ref.adamw_ref(
            flat, grads, m, v, lr, step, beta1=beta1, beta2=beta2, eps=eps,
            weight_decay=weight_decay)
    return new_flat, new_m, new_v, loss


def eval_step(cfg: ModelConfig, flat, tokens):
    return loss_fn(cfg, flat, tokens)


def make_train_step(cfg: ModelConfig):
    """Bind the config; returns ``f(flat, m, v, step, lr, tokens)``."""
    return functools.partial(train_step, cfg)


def make_eval_step(cfg: ModelConfig):
    return functools.partial(eval_step, cfg)


def flops_per_step(cfg: ModelConfig, batch: int) -> float:
    """Approximate training FLOPs (fwd+bwd ~= 3x fwd, 2 FLOPs/MAC)."""
    tokens = batch * cfg.seq
    dense = 2 * param_count(cfg) * tokens       # fwd matmuls
    attn = 2 * 2 * cfg.n_layer * tokens * cfg.seq * cfg.d_model  # QK^T + PV
    return 3.0 * (dense + attn)
