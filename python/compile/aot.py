"""AOT lowering: JAX train/eval/init functions -> HLO text artifacts.

Run once at build time (``make artifacts``); the Rust runtime
(``rust/src/runtime/``) loads the text with ``HloModuleProto::from_text_file``
compiles on the PJRT CPU client, and executes. Python never runs again.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the published ``xla`` 0.1.6 crate) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Outputs:
  artifacts/<name>.hlo.txt   one per (kind x model x batch)
  artifacts/manifest.json    machine-readable index consumed by Rust

Set ``SATURN_AOT_FULL=1`` to also emit the `base` (~29M param) artifacts.
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _tensor_spec(name, dtype, shape):
    return {"name": name, "dtype": dtype, "shape": list(shape)}


def lower_artifacts(cfg: M.ModelConfig, batch_sizes, out_dir):
    """Lower init/train/eval for one model config; return manifest entries."""
    P = M.padded_param_count(cfg)
    entries = []

    def dump(name, lowered, inputs, outputs, kind, bs=None):
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entries.append({
            "name": name, "file": fname, "kind": kind, "model": cfg.name,
            "batch": bs, "seq": cfg.seq, "vocab": cfg.vocab,
            "d_model": cfg.d_model, "n_head": cfg.n_head,
            "n_layer": cfg.n_layer,
            "param_count": M.param_count(cfg), "padded_params": P,
            "flops_per_step": M.flops_per_step(cfg, bs) if bs else 0.0,
            "inputs": inputs, "outputs": outputs,
        })
        print(f"  wrote {fname} ({len(text)/1e6:.1f} MB)")

    flat_spec = jax.ShapeDtypeStruct((P,), jnp.float32)
    scalar = jax.ShapeDtypeStruct((), jnp.float32)

    init = jax.jit(lambda seed: M.init_params(cfg, seed))
    dump(f"init_{cfg.name}",
         init.lower(jax.ShapeDtypeStruct((), jnp.int32)),
         [_tensor_spec("seed", "i32", ())],
         [_tensor_spec("flat", "f32", (P,))], "init")

    for bs in batch_sizes:
        tok_spec = jax.ShapeDtypeStruct((bs, cfg.seq), jnp.int32)
        train = jax.jit(M.make_train_step(cfg),
                        donate_argnums=(0, 1, 2))  # reuse param/opt buffers
        dump(f"train_{cfg.name}_bs{bs}",
             train.lower(flat_spec, flat_spec, flat_spec, scalar, scalar,
                         tok_spec),
             [_tensor_spec("flat", "f32", (P,)),
              _tensor_spec("m", "f32", (P,)),
              _tensor_spec("v", "f32", (P,)),
              _tensor_spec("step", "f32", ()),
              _tensor_spec("lr", "f32", ()),
              _tensor_spec("tokens", "i32", (bs, cfg.seq))],
             [_tensor_spec("flat", "f32", (P,)),
              _tensor_spec("m", "f32", (P,)),
              _tensor_spec("v", "f32", (P,)),
              _tensor_spec("loss", "f32", ())], "train", bs)

    bs = batch_sizes[0]
    tok_spec = jax.ShapeDtypeStruct((bs, cfg.seq), jnp.int32)
    evalf = jax.jit(M.make_eval_step(cfg))
    dump(f"eval_{cfg.name}_bs{bs}",
         evalf.lower(flat_spec, tok_spec),
         [_tensor_spec("flat", "f32", (P,)),
          _tensor_spec("tokens", "i32", (bs, cfg.seq))],
         [_tensor_spec("loss", "f32", ())], "eval", bs)
    return entries


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts",
                    help="output directory for HLO text + manifest")
    ap.add_argument("--models", default=None,
                    help="comma-separated config names (default: tiny,small"
                         " [+base with SATURN_AOT_FULL=1])")
    args = ap.parse_args()

    plan = {"tiny": [8], "small": [8, 16]}
    if os.environ.get("SATURN_AOT_FULL") == "1":
        plan["base"] = [8]
    if args.models:
        names = args.models.split(",")
        plan = {n: plan.get(n, [8]) for n in names}

    os.makedirs(args.out, exist_ok=True)
    entries = []
    for name, batches in plan.items():
        cfg = M.CONFIGS[name]
        print(f"lowering {name}: P={M.padded_param_count(cfg)} "
              f"({M.param_count(cfg)} real params)")
        entries += lower_artifacts(cfg, batches, args.out)

    manifest = {
        "version": 1,
        "pad_multiple": M.PAD_MULTIPLE,
        "artifacts": entries,
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest.json with {len(entries)} artifacts")


if __name__ == "__main__":
    main()
