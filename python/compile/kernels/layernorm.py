"""L1 Pallas kernel: fused LayerNorm forward (with custom-VJP backward).

The forward pass fuses mean/variance/normalize/scale/shift into one VMEM
pass over each row block instead of the 4-5 HLO ops XLA would otherwise
materialize. The backward uses the closed-form jnp expression (cheap,
fusible) via ``jax.custom_vjp`` -- Pallas kernels define no autodiff rules,
so the VJP wiring is explicit.

``interpret=True`` everywhere (CPU PJRT cannot run Mosaic custom-calls).
Oracle: ``ref.layernorm_ref``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 128


def _ln_kernel(x_ref, g_ref, b_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)  # (rows, d)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    xc = x - mu
    var = jnp.mean(xc * xc, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    y = xc * inv * g_ref[...].astype(jnp.float32) + b_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def _ln_fwd_pallas(x2d, gamma, beta, eps, block_rows):
    rows, d = x2d.shape
    br = min(block_rows, rows)
    while rows % br != 0:
        br -= 1
    return pl.pallas_call(
        functools.partial(_ln_kernel, eps=eps),
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2d.shape, x2d.dtype),
        interpret=True,
    )(x2d, gamma, beta)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def layernorm(x, gamma, beta, eps=1e-5, block_rows=DEFAULT_BLOCK_ROWS):
    """Fused LayerNorm over the last axis.

    Args:
      x: ``(..., d)``.
      gamma, beta: ``(d,)`` scale and shift.
    """
    shape = x.shape
    y = _ln_fwd_pallas(x.reshape(-1, shape[-1]), gamma, beta, eps, block_rows)
    return y.reshape(shape)


def _layernorm_fwd(x, gamma, beta, eps, block_rows):
    y = layernorm(x, gamma, beta, eps, block_rows)
    return y, (x, gamma)


def _layernorm_bwd(eps, block_rows, res, dy):
    x, gamma = res
    shape = x.shape
    d = shape[-1]
    x = x.reshape(-1, d).astype(jnp.float32)
    dy = dy.reshape(-1, d).astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    xc = x - mu
    var = jnp.mean(xc * xc, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    xhat = xc * inv
    dgamma = jnp.sum(dy * xhat, axis=0)
    dbeta = jnp.sum(dy, axis=0)
    dxhat = dy * gamma.astype(jnp.float32)[None, :]
    dx = inv * (dxhat
                - jnp.mean(dxhat, axis=-1, keepdims=True)
                - xhat * jnp.mean(dxhat * xhat, axis=-1, keepdims=True))
    return (dx.reshape(shape).astype(res[0].dtype),
            dgamma.astype(gamma.dtype), dbeta.astype(gamma.dtype))


layernorm.defvjp(_layernorm_fwd, _layernorm_bwd)
