"""L1 Pallas kernel: fused AdamW parameter update.

One VMEM pass over the flat parameter vector updates (param, m, v) in place
of the ~10 separate elementwise HLO ops a naive optimizer emits. Runtime
hyper-parameters (the bias-corrected step size and the decoupled
weight-decay factor) arrive as a tiny ``(2,)`` tensor so a single AOT
artifact serves every learning rate in the model-selection grid -- this is
what lets Saturn's Trial Runner reuse one compiled executable across the
whole HPO sweep.

Static hyper-parameters (betas, eps) are baked in via closure.
``interpret=True`` as everywhere. Oracle: ``ref.adamw_ref``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 65536


def _adamw_kernel(p_ref, g_ref, m_ref, v_ref, sched_ref,
                  po_ref, mo_ref, vo_ref, *, beta1, beta2, eps):
    p = p_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    alpha = sched_ref[0]    # bias-corrected lr: lr * sqrt(1-b2^t)/(1-b1^t)
    lr_wd = sched_ref[1]    # lr * weight_decay (decoupled)
    m_new = beta1 * m + (1.0 - beta1) * g
    v_new = beta2 * v + (1.0 - beta2) * g * g
    update = alpha * m_new / (jnp.sqrt(v_new) + eps) + lr_wd * p
    po_ref[...] = (p - update).astype(po_ref.dtype)
    mo_ref[...] = m_new.astype(mo_ref.dtype)
    vo_ref[...] = v_new.astype(vo_ref.dtype)


def adamw_update(params, grads, m, v, sched, *, beta1=0.9, beta2=0.999,
                 eps=1e-8, block=DEFAULT_BLOCK):
    """Fused AdamW step over flat f32 vectors.

    Args:
      params, grads, m, v: flat ``(n,)`` vectors, ``n`` need not be a block
        multiple (the grid clamps to divisors).
      sched: ``(2,)`` f32: ``[alpha_t, lr*weight_decay]`` where
        ``alpha_t = lr * sqrt(1 - beta2**t) / (1 - beta1**t)``.

    Returns:
      ``(new_params, new_m, new_v)``.
    """
    n = params.shape[0]
    b = min(block, n)
    while n % b != 0:
        b -= 1
    vec = pl.BlockSpec((b,), lambda i: (i,))
    out = pl.pallas_call(
        functools.partial(_adamw_kernel, beta1=beta1, beta2=beta2, eps=eps),
        grid=(n // b,),
        in_specs=[vec, vec, vec, vec, pl.BlockSpec((2,), lambda i: (0,))],
        out_specs=[vec, vec, vec],
        out_shape=[jax.ShapeDtypeStruct((n,), params.dtype)] * 3,
        interpret=True,
    )(params, grads, m, v, sched)
    return tuple(out)


def adamw_sched(lr, step, *, beta1=0.9, beta2=0.999, weight_decay=0.01):
    """Build the runtime ``(2,)`` schedule tensor for :func:`adamw_update`.

    ``step`` is the 1-based optimizer step (f32 scalar tensor ok).
    """
    t = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    alpha = lr * jnp.sqrt(1.0 - beta2 ** t) / (1.0 - beta1 ** t)
    return jnp.stack([alpha, lr * weight_decay]).astype(jnp.float32)
