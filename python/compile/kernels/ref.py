"""Pure-jnp oracles for every L1 Pallas kernel.

These are the CORE correctness signal of the build path: pytest asserts the
Pallas kernels match these references across shape/dtype sweeps (see
``python/tests/test_kernels.py``), and the L2 model has a ``use_kernels=False``
mode wired to these for end-to-end cross-checks.
"""

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, sm_scale=None, causal=True):
    """Naive softmax attention; materializes the full score matrix."""
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * sm_scale
    if causal:
        seq_q, seq_k = s.shape[-2], s.shape[-1]
        mask = jnp.tril(jnp.ones((seq_q, seq_k), dtype=bool))
        s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def layernorm_ref(x, gamma, beta, eps=1e-5):
    """Reference LayerNorm over the last axis."""
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(x.dtype)


def adamw_ref(params, grads, m, v, lr, step, *, beta1=0.9, beta2=0.999,
              eps=1e-8, weight_decay=0.01):
    """Reference decoupled AdamW step (1-based ``step``)."""
    p32, g32 = params.astype(jnp.float32), grads.astype(jnp.float32)
    m_new = beta1 * m + (1.0 - beta1) * g32
    v_new = beta2 * v + (1.0 - beta2) * g32 * g32
    alpha = lr * jnp.sqrt(1.0 - beta2 ** step) / (1.0 - beta1 ** step)
    update = alpha * m_new / (jnp.sqrt(v_new) + eps) + lr * weight_decay * p32
    return (p32 - update).astype(params.dtype), m_new, v_new
