"""L1 Pallas kernel: causal flash attention (forward + backward).

This is the compute hot-spot of Saturn's transformer workloads. The paper's
evaluation models (GPT-2 / GPT-J / ViT) are attention-dominated; on the
authors' A100 testbed the hot path is a CUDA fused-attention kernel. Per the
hardware-adaptation rule we re-think it for the TPU execution model instead
of porting warp-level code:

  * HBM <-> VMEM staging is expressed with ``BlockSpec`` + a 4-D grid
    ``(batch, head, q_block, k_block)`` instead of CUDA threadblocks.
  * The score matrix ``S = QK^T`` is never materialized in HBM: each
    ``(block_q, block_k)`` tile lives in VMEM scratch, and the online
    softmax carry (m, l, acc) persists across the sequential ``k_block``
    grid axis -- the Pallas-TPU idiom for a reduction loop.
  * Tiles default to MXU-friendly multiples (128 lanes); for the short
    sequences used in CPU-interpret tests any divisor of ``seq`` works.

``interpret=True`` is mandatory in this repo: real TPU lowering emits a
Mosaic custom-call which the CPU PJRT plugin (and the rust ``xla`` crate)
cannot execute. Interpret mode lowers to plain HLO, so the kernel rides
along inside the AOT ``train_step`` artifact executed from Rust.

Correctness oracle: ``ref.attention_ref`` (pure jnp) -- see
``python/tests/test_kernels.py``.
"""

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 64
DEFAULT_BLOCK_K = 64

_NEG_INF = -1e30


def _pick_block(seq_len: int, preferred: int) -> int:
    """Largest divisor of ``seq_len`` that is <= preferred (tiles must tile)."""
    b = min(preferred, seq_len)
    while seq_len % b != 0:
        b -= 1
    return b


# ---------------------------------------------------------------------------
# Forward kernel
# ---------------------------------------------------------------------------


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, acc_ref, m_ref, l_ref,
                *, sm_scale, block_q, block_k, causal):
    i = pl.program_id(2)  # q block index
    j = pl.program_id(3)  # k block index (sequential reduction axis)
    nk = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # Causal: block (i, j) contributes iff some q row >= some k col, i.e.
    # j*block_k <= i*block_q + block_q - 1.
    live = (j * block_k <= i * block_q + block_q - 1) if causal else True

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)  # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale  # (bq, bk)
        if causal:
            rows = i * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = j * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(rows >= cols, s, _NEG_INF)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        l = l_ref[...]
        # Guard fully-masked rows (cannot happen for causal self-attn, but
        # keeps the kernel total for padded inputs).
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe_l[:, None]).astype(o_ref.dtype)
        lse_ref[0, 0] = (m_ref[...] + jnp.log(safe_l)).astype(lse_ref.dtype)


def _flash_fwd(q, k, v, *, sm_scale, block_q, block_k, causal):
    batch, heads, seq, dim = q.shape
    bq = _pick_block(seq, block_q)
    bk = _pick_block(seq, block_k)
    nq, nk = seq // bq, seq // bk
    grid = (batch, heads, nq, nk)

    out, lse = pl.pallas_call(
        functools.partial(_fwd_kernel, sm_scale=sm_scale, block_q=bq,
                          block_k=bk, causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, dim), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, dim), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, dim), lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, dim), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, i, j: (b, h, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(q.shape, q.dtype),
            jax.ShapeDtypeStruct((batch, heads, seq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, dim), jnp.float32),  # acc
            pltpu.VMEM((bq,), jnp.float32),      # running max m
            pltpu.VMEM((bq,), jnp.float32),      # running sum l
        ],
        interpret=True,
    )(q, k, v)
    return out, lse


# ---------------------------------------------------------------------------
# Backward kernels: dK/dV sweep (grid over k blocks) and dQ sweep.
# ---------------------------------------------------------------------------


def _bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                    dk_ref, dv_ref, dk_acc, dv_acc,
                    *, sm_scale, block_q, block_k, causal):
    j = pl.program_id(2)  # k block (outer)
    i = pl.program_id(3)  # q block (sequential reduction axis)
    nq = pl.num_programs(3)

    @pl.when(i == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    live = (i * block_q + block_q - 1 >= j * block_k) if causal else True

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0].astype(jnp.float32)      # (bq,)
        delta = delta_ref[0, 0].astype(jnp.float32)  # (bq,)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale
        if causal:
            rows = i * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = j * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(rows >= cols, s, _NEG_INF)
        p = jnp.exp(s - lse[:, None])  # (bq, bk)
        dv_acc[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(
            do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * sm_scale
        dk_acc[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(i == nq - 1)
    def _finalize():
        dk_ref[0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


def _bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                   dq_ref, dq_acc, *, sm_scale, block_q, block_k, causal):
    i = pl.program_id(2)  # q block (outer)
    j = pl.program_id(3)  # k block (sequential reduction axis)
    nk = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    live = (j * block_k <= i * block_q + block_q - 1) if causal else True

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0].astype(jnp.float32)
        delta = delta_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale
        if causal:
            rows = i * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = j * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(rows >= cols, s, _NEG_INF)
        p = jnp.exp(s - lse[:, None])
        dp = jax.lax.dot_general(
            do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * sm_scale
        dq_acc[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(j == nk - 1)
    def _finalize():
        dq_ref[0, 0] = dq_acc[...].astype(dq_ref.dtype)


def _flash_bwd(res, do, *, sm_scale, block_q, block_k, causal):
    q, k, v, out, lse = res
    batch, heads, seq, dim = q.shape
    bq = _pick_block(seq, block_q)
    bk = _pick_block(seq, block_k)
    nq, nk = seq // bq, seq // bk

    # delta_i = rowsum(dO * O): O(S*d) elementwise, cheap -> plain jnp so it
    # fuses into the surrounding HLO.
    delta = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)

    q_spec = pl.BlockSpec((1, 1, bq, dim), lambda b, h, j, i: (b, h, i, 0))
    k_spec = pl.BlockSpec((1, 1, bk, dim), lambda b, h, j, i: (b, h, j, 0))
    row_spec = pl.BlockSpec((1, 1, bq), lambda b, h, j, i: (b, h, i))

    dk, dv = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, sm_scale=sm_scale, block_q=bq,
                          block_k=bk, causal=causal),
        grid=(batch, heads, nk, nq),
        in_specs=[q_spec, k_spec, k_spec, q_spec, row_spec, row_spec],
        out_specs=[
            pl.BlockSpec((1, 1, bk, dim), lambda b, h, j, i: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, dim), lambda b, h, j, i: (b, h, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(k.shape, k.dtype),
            jax.ShapeDtypeStruct(v.shape, v.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((bk, dim), jnp.float32),
            pltpu.VMEM((bk, dim), jnp.float32),
        ],
        interpret=True,
    )(q, k, v, do, lse, delta)

    q_spec2 = pl.BlockSpec((1, 1, bq, dim), lambda b, h, i, j: (b, h, i, 0))
    k_spec2 = pl.BlockSpec((1, 1, bk, dim), lambda b, h, i, j: (b, h, j, 0))
    row_spec2 = pl.BlockSpec((1, 1, bq), lambda b, h, i, j: (b, h, i))
    dq = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, sm_scale=sm_scale, block_q=bq,
                          block_k=bk, causal=causal),
        grid=(batch, heads, nq, nk),
        in_specs=[q_spec2, k_spec2, k_spec2, q_spec2, row_spec2, row_spec2],
        out_specs=pl.BlockSpec((1, 1, bq, dim), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, dim), jnp.float32)],
        interpret=True,
    )(q, k, v, do, lse, delta)

    return dq, dk, dv


# ---------------------------------------------------------------------------
# Public API: differentiable flash attention.
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q, k, v, sm_scale=None, block_q=DEFAULT_BLOCK_Q,
                    block_k=DEFAULT_BLOCK_K, causal=True):
    """Causal multi-head flash attention.

    Args:
      q, k, v: ``(batch, heads, seq, head_dim)``.
      sm_scale: softmax scale; defaults to ``1/sqrt(head_dim)``.
      block_q, block_k: preferred VMEM tile sizes (clamped to divisors of
        ``seq``).
      causal: apply a causal mask.

    Returns:
      ``(batch, heads, seq, head_dim)`` attention output.
    """
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(q.shape[-1])
    out, _ = _flash_fwd(q, k, v, sm_scale=sm_scale, block_q=block_q,
                        block_k=block_k, causal=causal)
    return out


def _vjp_fwd(q, k, v, sm_scale, block_q, block_k, causal):
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(q.shape[-1])
    out, lse = _flash_fwd(q, k, v, sm_scale=sm_scale, block_q=block_q,
                          block_k=block_k, causal=causal)
    return out, (q, k, v, out, lse)


def _vjp_bwd(sm_scale, block_q, block_k, causal, res, do):
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(res[0].shape[-1])
    return _flash_bwd(res, do, sm_scale=sm_scale, block_q=block_q,
                      block_k=block_k, causal=causal)


flash_attention.defvjp(_vjp_fwd, _vjp_bwd)


def flash_attention_with_lse(q, k, v, sm_scale=None,
                             block_q=DEFAULT_BLOCK_Q, block_k=DEFAULT_BLOCK_K,
                             causal=True):
    """Non-differentiable variant that also returns the logsumexp rows."""
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(q.shape[-1])
    return _flash_fwd(q, k, v, sm_scale=sm_scale, block_q=block_q,
                      block_k=block_k, causal=causal)


def vmem_footprint_bytes(block_q: int, block_k: int, head_dim: int) -> int:
    """Estimated per-core VMEM bytes for the forward kernel (f32).

    Used by DESIGN.md / the L1 perf pass: q tile + k tile + v tile + acc
    scratch + (m, l) carries + o tile. The S=QK^T tile is a register-level
    temporary of the same order as acc; we count it once.
    """
    f32 = 4
    tiles = (block_q * head_dim      # q
             + 2 * block_k * head_dim  # k, v
             + 2 * block_q * head_dim  # acc scratch + o tile
             + block_q * block_k       # s/p tile
             + 2 * block_q)            # m, l
    return tiles * f32
