"""L2 model correctness: shapes, kernel-vs-ref path equivalence, training."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

TINY = M.CONFIGS["tiny"]
TINY_REF = dataclasses.replace(TINY, use_kernels=False)


def _tokens(seed, b, cfg=TINY):
    return jax.random.randint(jax.random.PRNGKey(seed), (b, cfg.seq), 0,
                              cfg.vocab)


class TestLayout:
    def test_param_count_formula(self):
        # hand-computed for tiny: embeddings + per-layer blocks + final ln
        d, v, s, L = TINY.d_model, TINY.vocab, TINY.seq, TINY.n_layer
        per_layer = 2 * d + 3 * d * d + d * d + 2 * d + 4 * d * d + 4 * d \
            + 4 * d * d + d
        want = v * d + s * d + L * per_layer + 2 * d
        assert M.param_count(TINY) == want

    def test_padding_multiple(self):
        for cfg in M.CONFIGS.values():
            assert M.padded_param_count(cfg) % M.PAD_MULTIPLE == 0
            assert M.padded_param_count(cfg) >= M.param_count(cfg)

    def test_unflatten_shapes_and_coverage(self):
        flat = M.init_params(TINY, 0)
        p = M.unflatten(TINY, flat)
        layout = dict(M.param_layout(TINY))
        assert set(p) == set(layout)
        total = 0
        for name, arr in p.items():
            assert arr.shape == layout[name]
            total += arr.size
        assert total == M.param_count(TINY)

    def test_init_deterministic_and_layerwise(self):
        f1 = M.init_params(TINY, 42)
        f2 = M.init_params(TINY, 42)
        np.testing.assert_array_equal(f1, f2)
        p = M.unflatten(TINY, f1)
        np.testing.assert_allclose(p["h0.ln1_g"], 1.0)
        np.testing.assert_allclose(p["h0.b1"], 0.0)
        assert 0.01 < float(jnp.std(p["wte"])) < 0.03
        # padded tail is zero
        np.testing.assert_allclose(f1[M.param_count(TINY):], 0.0)


class TestForward:
    def test_logits_shape(self):
        flat = M.init_params(TINY, 0)
        logits = M.forward(TINY, flat, _tokens(0, 3))
        assert logits.shape == (3, TINY.seq, TINY.vocab)

    def test_kernel_and_ref_paths_agree(self):
        flat = M.init_params(TINY, 1)
        toks = _tokens(1, 2)
        a = M.forward(TINY, flat, toks)
        b = M.forward(TINY_REF, flat, toks)
        np.testing.assert_allclose(a, b, atol=5e-5, rtol=5e-5)

    def test_causality(self):
        # changing a future token must not affect earlier logits
        flat = M.init_params(TINY, 2)
        toks = _tokens(2, 1)
        toks2 = toks.at[0, -1].set((toks[0, -1] + 1) % TINY.vocab)
        a = M.forward(TINY, flat, toks)
        b = M.forward(TINY, flat, toks2)
        np.testing.assert_allclose(a[0, :-1], b[0, :-1], atol=1e-5)

    def test_initial_loss_near_uniform(self):
        flat = M.init_params(TINY, 3)
        loss = M.loss_fn(TINY, flat, _tokens(3, 4))
        assert abs(float(loss) - np.log(TINY.vocab)) < 0.3


class TestTrainStep:
    def test_loss_decreases(self):
        flat = M.init_params(TINY, 4)
        P = M.padded_param_count(TINY)
        m = v = jnp.zeros(P)
        toks = _tokens(4, 4)
        step = jax.jit(M.make_train_step(TINY))
        first = None
        for t in range(1, 6):
            flat, m, v, loss = step(flat, m, v, jnp.float32(t),
                                    jnp.float32(1e-3), toks)
            first = first or float(loss)
        assert float(loss) < first

    def test_grad_matches_ref_path(self):
        flat = M.init_params(TINY, 5)
        toks = _tokens(5, 2)
        g1 = jax.grad(lambda f: M.loss_fn(TINY, f, toks))(flat)
        g2 = jax.grad(lambda f: M.loss_fn(TINY_REF, f, toks))(flat)
        np.testing.assert_allclose(g1, g2, atol=2e-4, rtol=2e-3)

    def test_padded_region_untouched(self):
        flat = M.init_params(TINY, 6)
        P = M.padded_param_count(TINY)
        m = v = jnp.zeros(P)
        step = jax.jit(M.make_train_step(TINY))
        flat, m, v, _ = step(flat, m, v, jnp.float32(1), jnp.float32(1e-3),
                             _tokens(6, 2))
        np.testing.assert_allclose(flat[M.param_count(TINY):], 0.0)

    def test_lr_is_runtime_knob(self):
        # same artifact semantics: different lr -> different params, same fn
        flat0 = M.init_params(TINY, 7)
        P = M.padded_param_count(TINY)
        z = jnp.zeros(P)
        toks = _tokens(7, 2)
        step = jax.jit(M.make_train_step(TINY))
        a, *_ = step(flat0, z, z, jnp.float32(1), jnp.float32(1e-3), toks)
        b, *_ = step(flat0, z, z, jnp.float32(1), jnp.float32(1e-5), toks)
        assert float(jnp.max(jnp.abs(a - b))) > 0
        delta_a = float(jnp.mean(jnp.abs(a - flat0)))
        delta_b = float(jnp.mean(jnp.abs(b - flat0)))
        assert delta_a > delta_b  # larger lr moves further

    def test_eval_step_matches_loss(self):
        flat = M.init_params(TINY, 8)
        toks = _tokens(8, 2)
        np.testing.assert_allclose(M.eval_step(TINY, flat, toks),
                                   M.loss_fn(TINY, flat, toks))


def test_flops_model_sane():
    # small should cost more than tiny per step; both positive
    f_tiny = M.flops_per_step(M.CONFIGS["tiny"], 8)
    f_small = M.flops_per_step(M.CONFIGS["small"], 8)
    assert 0 < f_tiny < f_small
