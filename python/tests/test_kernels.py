"""L1 kernel correctness: Pallas vs pure-jnp oracles (ref.py).

Hypothesis sweeps shapes/dtypes per the build contract; every assertion is
an ``assert_allclose`` against the reference implementation.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.flash_attention import (
    flash_attention, flash_attention_with_lse, vmem_footprint_bytes)
from compile.kernels.fused_adamw import adamw_sched, adamw_update
from compile.kernels.layernorm import layernorm

SETTINGS = dict(max_examples=12, deadline=None)


def _qkv(seed, b, h, s, d, dtype=jnp.float32):
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    return [jax.random.normal(k, (b, h, s, d), dtype) for k in keys]


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


class TestFlashAttentionForward:
    @settings(**SETTINGS)
    @given(b=st.integers(1, 3), h=st.sampled_from([1, 2, 4]),
           s=st.sampled_from([16, 64, 96, 128]),
           d=st.sampled_from([8, 16, 32, 64]),
           seed=st.integers(0, 2**16))
    def test_matches_ref_causal(self, b, h, s, d, seed):
        q, k, v = _qkv(seed, b, h, s, d)
        out = flash_attention(q, k, v)
        want = ref.attention_ref(q, k, v)
        np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)

    @settings(**SETTINGS)
    @given(s=st.sampled_from([32, 64, 128]), seed=st.integers(0, 2**16))
    def test_matches_ref_noncausal(self, s, seed):
        q, k, v = _qkv(seed, 2, 2, s, 16)
        out = flash_attention(q, k, v, None, 64, 64, False)
        want = ref.attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)

    @pytest.mark.parametrize("bq,bk", [(16, 16), (32, 64), (64, 32), (128, 128)])
    def test_block_size_invariance(self, bq, bk):
        q, k, v = _qkv(3, 2, 2, 128, 32)
        out = flash_attention(q, k, v, None, bq, bk, True)
        want = ref.attention_ref(q, k, v)
        np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)

    def test_non_divisible_block_clamps(self):
        # seq=96 does not divide the default 64-block; _pick_block clamps.
        q, k, v = _qkv(4, 1, 2, 96, 16)
        out = flash_attention(q, k, v)
        np.testing.assert_allclose(out, ref.attention_ref(q, k, v),
                                   atol=2e-5, rtol=2e-5)

    def test_custom_scale(self):
        q, k, v = _qkv(5, 1, 1, 64, 16)
        out = flash_attention(q, k, v, 0.5)
        want = ref.attention_ref(q, k, v, sm_scale=0.5)
        np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)

    def test_lse_matches_ref(self):
        q, k, v = _qkv(6, 1, 2, 64, 16)
        _, lse = flash_attention_with_lse(q, k, v)
        s = jnp.einsum("bhqd,bhkd->bhqk", q, k) / np.sqrt(16)
        mask = jnp.tril(jnp.ones((64, 64), bool))
        s = jnp.where(mask, s, -jnp.inf)
        want = jax.scipy.special.logsumexp(s, axis=-1)
        np.testing.assert_allclose(lse, want, atol=2e-5, rtol=2e-5)

    def test_under_jit_and_vmap_compat(self):
        q, k, v = _qkv(7, 2, 2, 64, 16)
        out = jax.jit(flash_attention)(q, k, v)
        np.testing.assert_allclose(out, ref.attention_ref(q, k, v),
                                   atol=2e-5, rtol=2e-5)


class TestFlashAttentionBackward:
    @settings(**SETTINGS)
    @given(s=st.sampled_from([32, 64, 128]), d=st.sampled_from([8, 32]),
           seed=st.integers(0, 2**16))
    def test_grads_match_ref(self, s, d, seed):
        q, k, v = _qkv(seed, 2, 2, s, d)

        def f(att):
            def loss(q, k, v):
                return jnp.sum(jnp.tanh(att(q, k, v)))
            return jax.grad(loss, argnums=(0, 1, 2))(q, k, v)

        got = f(flash_attention)
        want = f(ref.attention_ref)
        for g, w, n in zip(got, want, "qkv"):
            np.testing.assert_allclose(g, w, atol=5e-5, rtol=5e-5,
                                       err_msg=f"d{n}")

    def test_grads_noncausal(self):
        q, k, v = _qkv(11, 1, 2, 64, 16)
        f = lambda q, k, v: jnp.sum(
            flash_attention(q, k, v, None, 64, 64, False) ** 2)
        fr = lambda q, k, v: jnp.sum(
            ref.attention_ref(q, k, v, causal=False) ** 2)
        got = jax.grad(f, (0, 1, 2))(q, k, v)
        want = jax.grad(fr, (0, 1, 2))(q, k, v)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, atol=5e-5, rtol=5e-5)

    def test_grad_through_jit(self):
        q, k, v = _qkv(12, 1, 1, 32, 8)
        g = jax.jit(jax.grad(lambda q: jnp.sum(flash_attention(q, k, v))))(q)
        gr = jax.grad(lambda q: jnp.sum(ref.attention_ref(q, k, v)))(q)
        np.testing.assert_allclose(g, gr, atol=5e-5, rtol=5e-5)


def test_vmem_footprint_model():
    # DESIGN.md L1 target: default tile fits comfortably in 16 MiB VMEM.
    assert vmem_footprint_bytes(128, 128, 64) < 2 * 1024 * 1024
    assert vmem_footprint_bytes(128, 128, 128) < 4 * 1024 * 1024


# ---------------------------------------------------------------------------
# layernorm
# ---------------------------------------------------------------------------


class TestLayerNorm:
    @settings(**SETTINGS)
    @given(rows=st.sampled_from([1, 7, 64, 200]),
           d=st.sampled_from([16, 128, 256]),
           seed=st.integers(0, 2**16))
    def test_matches_ref(self, rows, d, seed):
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        x = jax.random.normal(ks[0], (rows, d)) * 3 + 1
        g = jax.random.normal(ks[1], (d,)) * 0.2 + 1
        b = jax.random.normal(ks[2], (d,)) * 0.2
        np.testing.assert_allclose(layernorm(x, g, b),
                                   ref.layernorm_ref(x, g, b),
                                   atol=1e-5, rtol=1e-5)

    def test_3d_input(self):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        x = jax.random.normal(ks[0], (4, 32, 64))
        g = jnp.ones(64)
        b = jnp.zeros(64)
        np.testing.assert_allclose(layernorm(x, g, b),
                                   ref.layernorm_ref(x, g, b),
                                   atol=1e-5, rtol=1e-5)

    def test_grads_match_ref(self):
        ks = jax.random.split(jax.random.PRNGKey(9), 3)
        x = jax.random.normal(ks[0], (16, 32))
        g = jax.random.normal(ks[1], (32,)) * 0.1 + 1
        b = jax.random.normal(ks[2], (32,)) * 0.1
        f = lambda x, g, b: jnp.sum(jnp.sin(layernorm(x, g, b)))
        fr = lambda x, g, b: jnp.sum(jnp.sin(ref.layernorm_ref(x, g, b)))
        got = jax.grad(f, (0, 1, 2))(x, g, b)
        want = jax.grad(fr, (0, 1, 2))(x, g, b)
        for gg, ww in zip(got, want):
            np.testing.assert_allclose(gg, ww, atol=1e-4, rtol=1e-4)

    def test_normalization_invariants(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 128)) * 10 + 5
        y = layernorm(x, jnp.ones(128), jnp.zeros(128))
        np.testing.assert_allclose(jnp.mean(y, -1), 0.0, atol=1e-5)
        np.testing.assert_allclose(jnp.std(y, -1), 1.0, atol=1e-3)


# ---------------------------------------------------------------------------
# fused AdamW
# ---------------------------------------------------------------------------


class TestFusedAdamW:
    @settings(**SETTINGS)
    @given(n=st.sampled_from([3, 100, 4096, 70000]),
           lr=st.sampled_from([1e-5, 1e-4, 1e-3]),
           step=st.integers(1, 500), seed=st.integers(0, 2**16))
    def test_matches_ref(self, n, lr, step, seed):
        ks = jax.random.split(jax.random.PRNGKey(seed), 4)
        p = jax.random.normal(ks[0], (n,))
        g = jax.random.normal(ks[1], (n,))
        m = jax.random.normal(ks[2], (n,)) * 0.1
        v = jnp.abs(jax.random.normal(ks[3], (n,))) * 0.01
        sched = adamw_sched(lr, jnp.float32(step))
        got = adamw_update(p, g, m, v, sched)
        want = ref.adamw_ref(p, g, m, v, lr, float(step))
        for a, b in zip(got, want):
            np.testing.assert_allclose(a, b, atol=1e-6, rtol=1e-5)

    def test_zero_grad_pure_decay(self):
        n = 128
        p = jnp.ones(n)
        z = jnp.zeros(n)
        sched = adamw_sched(1e-2, jnp.float32(1), weight_decay=0.5)
        p2, m2, v2 = adamw_update(p, z, z, z, sched)
        np.testing.assert_allclose(p2, p * (1 - 1e-2 * 0.5), rtol=1e-6)
        np.testing.assert_allclose(m2, 0.0)
        np.testing.assert_allclose(v2, 0.0)

    def test_multi_step_sequence_matches_ref(self):
        n = 1000
        ks = jax.random.split(jax.random.PRNGKey(5), 2)
        p = pr = jax.random.normal(ks[0], (n,))
        m = v = mr = vr = jnp.zeros(n)
        for t in range(1, 6):
            g = jax.random.normal(jax.random.fold_in(ks[1], t), (n,))
            p, m, v = adamw_update(p, g, m, v, adamw_sched(1e-3, jnp.float32(t)))
            pr, mr, vr = ref.adamw_ref(pr, g, mr, vr, 1e-3, float(t))
        np.testing.assert_allclose(p, pr, atol=1e-5, rtol=1e-5)
